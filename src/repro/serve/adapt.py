"""Online-adaptation serving tier: continuously re-tuned schedule
selection under drifting traffic (ROADMAP item 1).

The paper pitches its FiCCO heuristics as signals "frameworks and
runtimes can harness"; :mod:`repro.autotune` made that a tiered runtime
tuner, and :mod:`repro.obs` (PR 7) gave it live signals — per-tier pick
counters, pick-latency histograms, gate-vs-argmin agreement, a
replayable audit log.  This module closes the loop for a long-lived
serving process whose traffic *drifts*:

* :class:`DecisionCache` — a bounded in-memory decision store keyed by
  :class:`~repro.autotune.tuner.TuneKey` strings, LRU eviction + TTL.
  The persistent :class:`~repro.autotune.cache.AutotuneCache` is only a
  **warm-start** (preloaded at construction) and **write-behind** layer
  (``persist="defer"`` puts, flushed by the re-fit thread and atexit) —
  the hot path never touches disk.
* :class:`AdaptiveTier` — the pick path: memory hit -> analytic re-rank
  with the *currently deployed* gate/model -> (budgeted) measured tier.
  TTL expiry is what makes selection adaptive: a stale decision is
  re-ranked rather than served forever, so machine-model re-fits and
  gate swaps actually reach future picks.
* :class:`Refitter` — a background daemon thread that periodically (a)
  retrains the :class:`~repro.learn.gate.LearnedGate` from a bounded
  buffer of *live* request scenarios and atomically swaps it into the
  tuner, (b) re-runs :func:`~repro.learn.fit.fit_machine` over live
  ``Autotuner.measure`` records to tighten the analytic error bar, and
  (c) flushes the write-behind layer.  Swaps are single attribute
  stores — request threads see the old or the new artifact, never a
  torn one.
* :class:`ExplorationPolicy` — the measured-tier policy that was still
  open: ``measure()`` fires only when the analytic shortlist's top-2
  gap is inside the fitted machine model's log-time error bar (the
  model genuinely cannot separate the candidates) AND a token-bucket
  budget allows it — so exploration is bounded per wall-clock second no
  matter how hard traffic drifts.

Synthetic drifting traffic comes from
:func:`repro.sweep.synth.drifting_request_stream`;
``benchmarks/bench_serve.py`` reports sustained decisions/sec and the
adaptation lag (picks until gate agreement recovers after a drift
step).  Metric namespace (beside PR 7's ``tuner/pick.*``)::

  serve/adapt.decisions        total tier picks
  serve/adapt.pick.<tier>      memory | warm | analytic | measured | heuristic
  serve/adapt.pick_seconds     per-pick wall-time histogram
  serve/adapt.expired          TTL re-ranks (staleness-driven adaptation)
  serve/adapt.evicted          LRU evictions (bounded-memory proof)
  serve/adapt.measures         exploration-budget measured sessions
  serve/adapt.refits,.gate_swaps  background re-fit activity
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.autotune.tuner import Autotuner, TuneDecision, TuneKey
from repro.core.heuristics import select_schedule
from repro.core.machine import TPU_V5E, MachineSpec, machine_for_group
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape, StepProfile
from repro.obs import audit as _audit
from repro.obs import metrics as _metrics
from repro.obs import signature as _signature
from repro.obs import trace as _trace
from repro.obs.sentinel import Sentinel, SentinelConfig


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Knobs of the online-adaptation tier (README "Online adaptation")."""

    cache_size: int = 4096        # in-memory decision bound (LRU beyond)
    ttl_s: float = 300.0          # decision freshness; expiry -> re-rank
    refit_interval_s: float = 2.0  # background re-fit cadence
    refit_min_picks: int = 64     # buffered scenarios before a gate retrain
    buffer_size: int = 2048       # live-scenario buffer bound (newest win)
    explore_rate: float = 1.0     # measured-tier token-bucket refill /s
    explore_burst: float = 8.0    # token-bucket capacity
    error_bar_z: float = 2.0      # top-2 gap within z*sigma -> explore
    default_sigma: float = 0.10   # log-time error bar before any fit
    fit_min_records: int = 6      # measured records before a machine re-fit
    fit_params: tuple[str, ...] = ("link_bw", "s_half")
    fit_steps: int = 120          # Adam steps per background re-fit
    gate_max_leaves: int = 8
    # Drift sentinel (repro.obs.sentinel): monitors measured-tier
    # residuals + gate agreement; an alarm kicks the Refitter awake so
    # a refit runs at drift time, not at the next wall-clock interval.
    sentinel: bool = True
    sentinel_k: float = 0.5       # CUSUM reference (sigma units)
    sentinel_h: float = 8.0       # CUSUM decision threshold
    sentinel_min_samples: int = 8  # residuals before alarms arm
    sentinel_agreement_floor: float = 0.5
    # Deploy machine re-fits: patch fitted scalar MachineSpec params
    # (e.g. link_bw) into the tier's machine so future analytic
    # rankings/predictions use the calibrated values — what makes a
    # drift-triggered refit actually shrink the residual.
    deploy_fit: bool = True

    def __post_init__(self):
        if self.cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {self.cache_size}")
        if self.ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {self.ttl_s}")


class TokenBucket:
    """Thread-safe token bucket: ``rate`` tokens/s up to ``burst``.

    ``try_take`` never blocks — a denied token means "serve the analytic
    answer now, explore later", which is the only acceptable behavior on
    a request path.
    """

    def __init__(self, rate: float, burst: float, *, clock=time.monotonic):
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._stamp = clock()
        self._lock = threading.Lock()

    def try_take(self, n: float = 1.0) -> bool:
        with self._lock:
            now = self._clock()
            self._tokens = min(
                self.burst, self._tokens + (now - self._stamp) * self.rate
            )
            self._stamp = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


class DecisionCache:
    """Bounded in-memory TuneKey -> decision store (LRU + TTL).

    A hit refreshes recency (LRU), never freshness: an entry older than
    ``ttl_s`` is dropped on lookup and the miss forces a re-rank under
    whatever gate/model the re-fit thread has deployed since — that is
    the adaptation mechanism, not a cache implementation detail.
    """

    def __init__(self, size: int, ttl_s: float, *, clock=time.monotonic):
        self.size = int(size)
        self.ttl_s = float(ttl_s)
        self._clock = clock
        self._data: "collections.OrderedDict[str, tuple[TuneDecision, float]]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self.expired = 0
        self.evicted = 0

    def get(self, key: str) -> Optional[TuneDecision]:
        with self._lock:
            item = self._data.get(key)
            if item is None:
                return None
            dec, expires = item
            if self._clock() >= expires:
                del self._data[key]
                self.expired += 1
                return None
            self._data.move_to_end(key)
            return dec

    def put(self, key: str, dec: TuneDecision) -> None:
        with self._lock:
            self._data[key] = (dec, self._clock() + self.ttl_s)
            self._data.move_to_end(key)
            while len(self._data) > self.size:
                self._data.popitem(last=False)
                self.evicted += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._data


class ExplorationPolicy:
    """Measured-tier policy: explore only when the model cannot decide
    AND the budget allows.

    The analytic ranking's top-2 candidates are worth measuring exactly
    when their modelled gap is inside the machine model's own error bar
    — ``|log(t2/t1)| <= z * sigma`` where ``sigma`` is the fitted
    model's RMS log-time error (:class:`~repro.learn.fit.FitResult`
    loss), updated by every background re-fit.  Even then a token
    bucket caps measured sessions per wall-clock second, so a drift
    step cannot stampede the measured tier.
    """

    def __init__(self, config: AdaptConfig, *, clock=time.monotonic):
        self._z = float(config.error_bar_z)
        self._sigma = float(config.default_sigma)
        self._bucket = TokenBucket(
            config.explore_rate, config.explore_burst, clock=clock
        )
        self.ambiguous = 0   # picks whose top-2 gap was inside the bar
        self.granted = 0     # ... that the budget actually let explore
        self.denied = 0      # ... denied by the token bucket

    @property
    def sigma(self) -> float:
        return self._sigma

    def set_sigma(self, sigma: float) -> None:
        """Atomic swap of the error bar (the re-fit thread's hook)."""
        self._sigma = max(float(sigma), 1e-6)

    def should_measure(self, ranked: Sequence[tuple[Schedule, float]]) -> bool:
        if len(ranked) < 2:
            return False
        t1, t2 = float(ranked[0][1]), float(ranked[1][1])
        if t1 <= 0.0 or t2 <= 0.0:
            return False
        if abs(math.log(t2 / t1)) > self._z * self._sigma:
            return False  # the model separates them confidently
        self.ambiguous += 1
        if self._bucket.try_take():
            self.granted += 1
            return True
        self.denied += 1
        return False


class AdaptiveTier:
    """The continuously-adapting schedule-selection tier.

    ``tuner`` supplies the analytic ranking, the learned-gate slot the
    re-fit thread swaps, and the persistent cache used as warm-start +
    write-behind (it is constructed with ``persist="defer"`` when not
    given).  ``measure_fn(gemm, candidates, profile) -> {Schedule:
    seconds}`` is the measured-tier hook — wrap
    :meth:`~repro.autotune.tuner.Autotuner.measure` in a real
    deployment, or a simulator in benchmarks; ``None`` disables the
    measured tier regardless of budget.

    ``clock`` injects time for TTL/budget tests (monotonic seconds).
    Use as a context manager to scope the background re-fit thread::

        with AdaptiveTier(machine=machine) as tier:
            for req in stream:
                tier.pick(req.gemm, profile=req.profile)
    """

    def __init__(
        self,
        tuner: Autotuner | None = None,
        *,
        machine: MachineSpec | None = None,
        group: int | None = None,
        config: AdaptConfig | None = None,
        measure_fn: Callable | None = None,
        clock=time.monotonic,
        backend: str = "numpy",
    ):
        self.config = config or AdaptConfig()
        self.machine = machine or TPU_V5E
        self.group = group
        self.tuner = tuner if tuner is not None else Autotuner(
            backend=backend, persist="defer"
        )
        self.measure_fn = measure_fn
        self._clock = clock
        self.cache = DecisionCache(
            self.config.cache_size, self.config.ttl_s, clock=clock
        )
        self.policy = ExplorationPolicy(self.config, clock=clock)
        # Live-scenario buffer the gate retrain trains on: newest
        # ``buffer_size`` (gemm, frac-or-None) pairs, i.e. the traffic
        # *after* a drift step quickly dominates.
        self._buffer: collections.deque = collections.deque(
            maxlen=self.config.buffer_size
        )
        self._buffer_lock = threading.Lock()
        self._refitter: Refitter | None = None
        self.gate_version = 0
        self.last_agreement: float | None = None
        self.sentinel: Sentinel | None = (
            Sentinel(SentinelConfig(
                k=self.config.sentinel_k,
                h=self.config.sentinel_h,
                min_samples=self.config.sentinel_min_samples,
                sigma0=self.config.default_sigma,
                agreement_floor=self.config.sentinel_agreement_floor,
            ))
            if self.config.sentinel
            else None
        )
        self.fit_deployed: list[str] = []
        self._warm_start()

    # -- lifecycle -------------------------------------------------------

    def start(self) -> "AdaptiveTier":
        """Start the background re-fit thread (idempotent).

        With a sentinel configured, its alarm hook kicks the re-fit
        thread awake immediately — drift triggers a refit at alarm
        time, not at the next wall-clock interval.
        """
        if self._refitter is None or not self._refitter.is_alive():
            self._refitter = Refitter(self)
            self._refitter.start()
        if self.sentinel is not None:
            self.sentinel.on_alarm = self._refitter.kick
        return self

    def stop(self) -> None:
        """Stop the re-fit thread and flush the write-behind layer."""
        if self.sentinel is not None:
            self.sentinel.on_alarm = None
        if self._refitter is not None:
            self._refitter.stop()
            self._refitter = None
        self.tuner.cache.flush()

    def __enter__(self) -> "AdaptiveTier":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- warm start ------------------------------------------------------

    def _warm_start(self) -> None:
        """Pre-seed the memory tier from the persistent store.

        The persistent cache is the cross-process memory; decisions it
        holds enter the LRU with a normal TTL, so they serve instantly
        on startup and still age out into re-ranks like any other
        entry.
        """
        reg = _metrics.get_metrics()
        n = 0
        for key, entry in self.tuner.cache.decision_entries().items():
            try:
                sched = Schedule(entry["schedule"])
            except (KeyError, ValueError):
                continue
            self.cache.put(
                key,
                TuneDecision(
                    sched,
                    "cache",
                    entry.get("model_total_s"),
                    entry.get("measured_total_s"),
                    key=key,
                ),
            )
            n += 1
            if n >= self.config.cache_size:
                break
        if n:
            reg.counter("serve/adapt.warm_start").inc(n)

    # -- the pick path ---------------------------------------------------

    def pick(
        self,
        gemm: GemmShape,
        machine: MachineSpec | None = None,
        *,
        group: int | None = None,
        profile: StepProfile | None = None,
    ) -> TuneDecision:
        """Tiered adaptive pick.  Never raises (heuristic fallback)."""
        machine = machine or self.machine
        group = group if group is not None else self.group
        tkey = TuneKey.for_gemm(gemm, machine, group, profile=profile)
        key = str(tkey)
        t0 = time.perf_counter()
        reg = _metrics.get_metrics()
        with _trace.span("serve/adapt.pick", "serve", key=key) as sp:
            dec = self.cache.get(key)
            if dec is not None:
                tier = "memory"
            else:
                try:
                    dec, tier = self._rank_and_decide(
                        gemm, machine, key, group, profile
                    )
                except Exception:
                    # Never-raise contract (same as the tuner's): any
                    # engine/model failure degrades to the static
                    # heuristic, un-cached so a healthy pick re-ranks.
                    hdec = select_schedule(
                        gemm,
                        machine_for_group(machine, group) if group else machine,
                        profile=profile,
                    )
                    dec, tier = (
                        TuneDecision(hdec.schedule, "heuristic", key=key),
                        "heuristic",
                    )
            sp.set(tier=tier, schedule=dec.schedule.value)
        self._observe_scenario(gemm, profile)
        seconds = time.perf_counter() - t0
        try:
            reg.counter("serve/adapt.decisions").inc()
            reg.counter(f"serve/adapt.pick.{tier}").inc()
            reg.histogram("serve/adapt.pick_seconds").observe(seconds)
            stream = _signature.get_signatures()
            if stream is not None:
                stream.observe_decision(
                    gemm, machine, dec.schedule,
                    group=group, profile=profile, source=tier,
                    model_total_s=dec.model_total_s,
                    measured_total_s=dec.measured_total_s,
                )
        except Exception:  # pragma: no cover - observability best-effort
            pass
        return dec

    def _rank_and_decide(
        self, gemm, machine, key: str, group, profile
    ) -> tuple[TuneDecision, str]:
        ranked = self.tuner.executable_ranking(
            gemm, machine, group=group, profile=profile
        )
        if (
            self.measure_fn is not None
            and self.policy.should_measure(ranked)
        ):
            dec = self._measure(gemm, ranked, key, profile)
            if dec is not None:
                self.cache.put(key, dec)
                return dec, "measured"
        sched, model_t = ranked[0]
        dec = TuneDecision(
            sched, "analytic", model_t, key=key,
            shortlist=tuple((s.value, float(t)) for s, t in ranked[:3]),
        )
        self.cache.put(key, dec)
        # Write-behind: the persistent layer learns the decision without
        # hot-path disk I/O (the re-fit thread / atexit flushes).
        self.tuner.cache.put(
            key,
            {
                "schedule": sched.value,
                "source": "analytic",
                "model_total_s": float(model_t),
                "measured_total_s": None,
            },
            persist="defer",
        )
        return dec, "analytic"

    def _measure(self, gemm, ranked, key: str, profile):
        """Budgeted measured tier: time the top-2, record + audit."""
        reg = _metrics.get_metrics()
        candidates = [s for s, _ in ranked[:2]]
        try:
            with _trace.span(
                "serve/adapt.measure", "serve", key=key,
                candidates=[s.value for s in candidates],
            ):
                timings = self.measure_fn(gemm, candidates, profile)
        except Exception:
            return None
        if not timings:
            return None
        winner = min(timings, key=timings.get)
        best = float(timings[winner])
        model_t = dict(ranked).get(winner)
        # Every measured session is a predicted/measured pair — the
        # drift sentinel's residual channel.
        if self.sentinel is not None and model_t:
            self.sentinel.observe_residual(float(model_t), best, key=key)
        self.tuner.cache.put(
            key,
            {
                "schedule": winner.value,
                "source": "measured",
                "model_total_s": float(model_t) if model_t else None,
                "measured_total_s": best,
            },
            persist="defer",
        )
        dec = TuneDecision(
            winner, "measured",
            model_total_s=float(model_t) if model_t else None,
            measured_total_s=best, key=key,
            shortlist=tuple(
                (s.value, float(t))
                for s, t in sorted(timings.items(), key=lambda kv: kv[1])
            ),
        )
        try:
            reg.counter("serve/adapt.measures").inc()
            log = _audit.get_audit()
            if log is not None:
                log.record({
                    "kind": "adapt_measure",
                    "key": key,
                    "schedule": winner.value,
                    "source": "measured",
                    "measured_total_s": best,
                    "shortlist": [[s.value, float(t)]
                                  for s, t in timings.items()],
                })
        except Exception:  # pragma: no cover - observability best-effort
            pass
        return dec

    # -- DecodeEngine wiring ---------------------------------------------

    def pick_for_requests(self, requests, cfg) -> TuneDecision:
        """Schedule pick for one decode batch's request-load digest.

        The batch's per-request work shares (prompt + generation
        tokens) are the serving-side analog of an expert-load profile:
        quantized to 64ths so identical load *shapes* share a cache key
        even when absolute lengths differ slightly.  The GEMM is the
        batch's FFN workload (total token rows x d_model x d_ff).
        """
        work = [
            max(len(r.prompt) + r.max_new_tokens, 1) for r in requests
        ] or [1]
        total = sum(work)
        profile = None
        if len(work) > 1:
            counts = StepProfile.from_weights(work, name="reqload").quantize(64)
            profile = StepProfile(
                tuple(c / 64 for c in counts), name="reqload"
            )
        gemm = GemmShape(total, cfg.d_ff, cfg.d_model, 2)
        return self.pick(gemm, profile=profile)

    # -- re-fit ----------------------------------------------------------

    def _observe_scenario(self, gemm, profile) -> None:
        frac = None if profile is None else tuple(profile.fractions)
        with self._buffer_lock:
            self._buffer.append(
                (gemm.m, gemm.n, gemm.k, gemm.dtype_bytes, frac)
            )

    def _snapshot_buffer(self):
        with self._buffer_lock:
            return list(self._buffer)

    def refit_now(self) -> dict:
        """One re-fit cycle, inline (what the background thread runs).

        Returns a report dict: ``gate_agreement`` (post-swap agreement
        on the live-traffic grid) and/or ``fit_sigma`` when the
        respective stage ran, plus ``flushed``.  Never raises.
        """
        reg = _metrics.get_metrics()
        drift = (
            self.sentinel is not None and self.sentinel.should_refit()
        )
        out: dict = {"trigger": "drift" if drift else "interval"}
        try:
            out.update(self._refit_gate())
        except Exception:
            out["gate_error"] = True
        try:
            out.update(self._refit_machine())
        except Exception:
            out["fit_error"] = True
        try:
            self.tuner.cache.flush()
            out["flushed"] = True
        except Exception:
            out["flushed"] = False
        try:
            reg.counter("serve/adapt.refits").inc()
        except Exception:  # pragma: no cover
            pass
        # Close the sentinel loop: a drift-triggered cycle (or one that
        # actually re-fit the machine model) resets the CUSUM and arms
        # post-refit recovery tracking.  Interval cycles that did
        # nothing model-relevant (the common idle case) don't spam
        # refit events.
        if self.sentinel is not None and (drift or "fit_sigma" in out):
            try:
                self.sentinel.record_refit(out, trigger=out["trigger"])
            except Exception:  # pragma: no cover
                pass
        return out

    def _grid_from_rows(self, rows):
        """Evaluate live-traffic rows ``(m, n, k, b, frac-or-None)``
        into a decision grid on the tier's effective machine."""
        from repro.core.batch import RaggedBatch
        from repro.core.engine import get_engine

        eff = (
            machine_for_group(self.machine, self.group)
            if self.group
            else self.machine
        )
        g = eff.group
        width = max(
            [len(f) for *_abcd, f in rows if f is not None] + [g]
        )
        m = np.asarray([r[0] for r in rows], dtype=np.int64)
        n = np.asarray([r[1] for r in rows], dtype=np.int64)
        k = np.asarray([r[2] for r in rows], dtype=np.int64)
        b = np.asarray([r[3] for r in rows], dtype=np.int64)
        frac = np.zeros((len(rows), width))
        uni = np.zeros(width)
        uni[:g] = 1.0 / g
        for i, (*_abcd, f) in enumerate(rows):
            if f is None:
                frac[i] = uni
            else:
                frac[i, : len(f)] = f
        batch = RaggedBatch(m=m, n=n, k=k, dtype_bytes=b, frac=frac)
        return get_engine(self.tuner.backend).evaluate(batch, [eff])

    def agreement_probe(self, pairs) -> Optional[float]:
        """Deployed gate's agreement on held-out traffic.

        ``pairs`` is a sequence of ``(GemmShape, StepProfile | None)``.
        Unlike the agreement a re-fit reports (the gate's *training*
        grid), this evaluates the currently deployed gate on traffic it
        was not trained on — the honest adaptation-lag signal after a
        drift step.  Returns ``None`` until a re-fit has deployed a
        gate.
        """
        from repro.obs.metrics import observe_gate_agreement

        gate = self.tuner.gate
        if gate is None or not pairs:
            return None
        rows = [
            (
                g.m, g.n, g.k, g.dtype_bytes,
                None if p is None else tuple(p.fractions),
            )
            for g, p in pairs
        ]
        grid = self._grid_from_rows(rows)
        return observe_gate_agreement(grid, gate=gate)

    def _refit_gate(self) -> dict:
        from repro.learn.gate import GATE_ARTIFACT_KIND, train_gate
        from repro.obs.metrics import observe_gate_agreement

        rows = self._snapshot_buffer()
        if len(rows) < self.config.refit_min_picks:
            return {}
        with _trace.span(
            "serve/adapt.refit_gate", "serve", n_points=len(rows)
        ):
            grid = self._grid_from_rows(rows)
            gate = train_gate(
                grid, max_leaves=self.config.gate_max_leaves,
                meta={"trained_by": "serve.adapt", "n_live": len(rows)},
            )
            # Atomic swap: request threads see old or new, never torn.
            self.tuner.set_gate(gate)
            self.gate_version += 1
            agreement = observe_gate_agreement(grid, gate=gate)
        self.last_agreement = agreement
        if self.sentinel is not None:
            self.sentinel.observe_agreement(agreement)
        # Persist the deployed gate beside the decisions (write-behind).
        try:
            import json as _json

            self.tuner.cache.put_artifact(
                GATE_ARTIFACT_KIND,
                "adapt:" + self.machine.name.split("/", 1)[0],
                _json.loads(gate.to_json()),
                persist="defer",
            )
        except Exception:
            pass
        try:
            _metrics.get_metrics().counter("serve/adapt.gate_swaps").inc()
        except Exception:  # pragma: no cover
            pass
        return {"gate_agreement": agreement, "gate_points": len(rows)}

    def _refit_machine(self) -> dict:
        from repro.learn.fit import fit_machine, records_from_cache, save_fit

        records = records_from_cache(self.tuner.cache, self.machine.name)
        groups = {r.group for r in records}
        if len(records) < self.config.fit_min_records or len(groups) != 1:
            return {}
        with _trace.span(
            "serve/adapt.refit_machine", "serve", n_records=len(records)
        ):
            fit = fit_machine(
                self.machine, records,
                params=self.config.fit_params,
                steps=self.config.fit_steps,
            )
            # RMS log-time error IS the error bar the exploration
            # policy compares analytic gaps against — and the residual
            # scale the drift sentinel standardizes by.
            sigma = math.sqrt(max(fit.loss, 0.0))
            self.policy.set_sigma(sigma)
            if self.sentinel is not None:
                self.sentinel.set_sigma(sigma)
            save_fit(fit, cache=self.tuner.cache)
        out = {"fit_sigma": sigma, "fit_records": len(records)}
        deployed = self._deploy_fit(fit)
        if deployed:
            out["fit_deployed"] = ",".join(deployed)
        return out

    def _deploy_fit(self, fit) -> list[str]:
        """Patch fitted scalar MachineSpec params into the tier's
        machine (atomic attribute swap — request threads see the old or
        the new spec, never a torn one).

        Only fitted params that are real :class:`~repro.core.machine.
        MachineSpec` fields deploy this way (``link_bw`` is; ``s_half``
        is a derived calibration array, consumed through the persisted
        :class:`~repro.learn.fit.FitResult` instead).  The spec's name
        is preserved, so measured records keep accumulating under the
        same machine key.
        """
        if not self.config.deploy_fit:
            return []
        field_names = {
            f.name for f in dataclasses.fields(type(self.machine))
        }
        patch = {}
        for k, v in fit.fitted.items():
            if k not in field_names:
                continue
            try:
                patch[k] = float(v)  # accepts numpy/jax scalars too
            except (TypeError, ValueError):
                continue
        if not patch:
            return []
        self.machine = dataclasses.replace(self.machine, **patch)
        self.fit_deployed = sorted(patch)
        try:
            _metrics.get_metrics().counter("serve/adapt.fit_deploys").inc()
        except Exception:  # pragma: no cover
            pass
        return self.fit_deployed

    # -- reporting -------------------------------------------------------

    def stats(self) -> dict:
        """One self-describing view of the tier's state (launchers)."""
        return {
            "cache_len": len(self.cache),
            "cache_expired": self.cache.expired,
            "cache_evicted": self.cache.evicted,
            "gate_version": self.gate_version,
            "last_agreement": self.last_agreement,
            "sigma": self.policy.sigma,
            "explore_ambiguous": self.policy.ambiguous,
            "explore_granted": self.policy.granted,
            "explore_denied": self.policy.denied,
            "persistent_dirty": self.tuner.cache.dirty,
            "fit_deployed": list(self.fit_deployed),
            "sentinel": (
                None if self.sentinel is None else self.sentinel.state()
            ),
        }


class Refitter(threading.Thread):
    """Daemon thread running :meth:`AdaptiveTier.refit_now` on a cadence
    — or immediately when :meth:`kick`\\ ed (the drift sentinel's alarm
    hook), so a detected drift is acted on at alarm time instead of
    waiting out the wall-clock interval.

    ``stop()`` wakes the wait and joins; the final cycle's flush is the
    tier's (``AdaptiveTier.stop`` flushes after joining, so nothing
    recorded between the last cycle and the stop is lost).
    """

    def __init__(self, tier: AdaptiveTier):
        super().__init__(name="serve-adapt-refit", daemon=True)
        self.tier = tier
        # NB: not named ``_stop`` — Thread.join's internals call a
        # private ``_stop()`` method and an Event would shadow it.
        self._halt = threading.Event()
        self._kick = threading.Event()
        self.kicks = 0

    def kick(self) -> None:
        """Wake the thread for an immediate re-fit cycle (thread-safe;
        coalesces — multiple kicks before the wake run one cycle)."""
        self.kicks += 1
        self._kick.set()

    def run(self) -> None:
        while True:
            self._kick.wait(self.tier.config.refit_interval_s)
            self._kick.clear()
            if self._halt.is_set():
                return
            self.tier.refit_now()

    def stop(self, timeout: float = 10.0) -> None:
        self._halt.set()
        self._kick.set()  # wake the wait so the halt is seen now
        self.join(timeout=timeout)


def simulated_measure_fn(
    machine: MachineSpec,
    *,
    noise: float = 0.03,
    seed: int = 0,
    backend: str = "numpy",
):
    """A measured-tier hook backed by the analytic model + log-normal
    noise — the benchmark/test stand-in for timing real collectives
    (wrap :meth:`~repro.autotune.tuner.Autotuner.measure` in a real
    deployment).
    """
    from repro.core.engine import get_engine, shortlist as engine_shortlist

    eng = get_engine(backend)
    rng = np.random.default_rng(seed)

    def measure(gemm, candidates, profile):
        ranked = engine_shortlist(
            gemm, machine, top=None, engine=eng, profile=profile
        )
        times = {s: t for s, t in ranked}
        out = {}
        for sched in candidates:
            if sched in times:
                out[sched] = float(
                    times[sched] * np.exp(rng.normal(0.0, noise))
                )
        return out

    return measure


__all__ = [
    "AdaptConfig",
    "TokenBucket",
    "DecisionCache",
    "ExplorationPolicy",
    "AdaptiveTier",
    "Refitter",
    "simulated_measure_fn",
]
