"""Serving substrate: prefill + batched greedy decode engine.

``make_serve_step`` is what the decode dry-run shapes lower: ONE new token
against a ``seq_len`` KV cache.  The engine adds a minimal continuous-batch
loop on top for the runnable serving example.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model, build_model
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.parallel.context import overlap_context


def make_serve_step(model: Model) -> Callable:
    """(params, cache, tokens (B,1), pos) -> (logits, new_cache)."""

    def serve_step(params, cache, tokens, pos):
        with overlap_context(model.config.overlap):
            return model.decode_step(params, cache, tokens, pos)

    return serve_step


def make_prefill(model: Model) -> Callable:
    def prefill(params, batch):
        with overlap_context(model.config.overlap):
            logits, _ = model.forward(params, batch)
        return logits

    return prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    """Tiny batched greedy engine over the jitted serve_step.

    Prompts are fed token-by-token through the decode path (prefill via
    decode keeps the engine simple and exercises the cache exactly as the
    dry-run shapes do).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_size: int = 4,
        cache_len: int = 128,
        enc_len: int = 0,
        adapt=None,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.batch = batch_size
        self.cache_len = cache_len
        self.cache = self.model.init_cache(
            batch_size, cache_len, enc_len=enc_len
        )
        self.step_fn = jax.jit(make_serve_step(self.model))
        # Online-adaptation tier (repro.serve.adapt.AdaptiveTier): when
        # set, every run() streams its request-load digest through the
        # tier and records the tuned overlap schedule for the batch.
        self.adapt = adapt
        self.last_decision = None

    def run(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.batch
        # A batch whose requests want zero new tokens (all
        # max_new_tokens=0, or an empty/dummy-pad-only batch) has
        # nothing to emit — skip the decode loop entirely instead of
        # burning max_prompt + max_new jitted steps producing nothing.
        if not any(len(r.out) < r.max_new_tokens for r in requests):
            for r in requests:
                r.done = True
            return requests
        # left-align all prompts; pad batch with a dummy request
        reqs = list(requests) + [
            Request(np.zeros(1, np.int32), 0)
            for _ in range(self.batch - len(requests))
        ]
        max_prompt = max(len(r.prompt) for r in reqs)
        max_new = max((r.max_new_tokens for r in reqs), default=0)
        reg = _metrics.get_metrics()
        steps_c = reg.counter("serve/steps")
        tokens_c = reg.counter("serve/tokens")
        overlap_args = {}
        if self.adapt is not None:
            self.last_decision = self.adapt.pick_for_requests(
                requests, self.cfg
            )
            # Surface the batch's overlap decision on the run span so a
            # merged fleet trace reads which schedule served which
            # batch without joining against the audit log.  The hook is
            # duck-typed (tests stub it), so only annotate when the
            # decision actually carries a schedule.
            sched = getattr(self.last_decision, "schedule", None)
            if sched is not None:
                overlap_args = {
                    "overlap_schedule": sched.value,
                    "overlap_tier": self.last_decision.source,
                }
        with _trace.span(
            "serve/run", "serve",
            n_requests=len(requests), batch=self.batch,
            max_prompt=max_prompt, max_new=max_new, **overlap_args,
        ):
            for pos in range(max_prompt + max_new):
                feed = []
                for r in reqs:
                    if pos < len(r.prompt):
                        feed.append(r.prompt[pos])
                    elif r.out:
                        feed.append(r.out[-1])
                    else:
                        feed.append(0)
                tok = jnp.asarray(np.asarray(feed, np.int32)[:, None])
                with _trace.span("serve/step", "serve", pos=pos) as sp:
                    logits, self.cache = self.step_fn(
                        self.params, self.cache, tok, jnp.int32(pos)
                    )
                    nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
                    emitted = 0
                    for i, r in enumerate(reqs[: len(requests)]):
                        if (
                            pos >= len(r.prompt) - 1
                            and len(r.out) < r.max_new_tokens
                        ):
                            r.out.append(int(nxt[i]))
                            emitted += 1
                    sp.set(tokens=emitted)
                steps_c.inc()
                tokens_c.inc(emitted)
                if all(
                    len(r.out) >= r.max_new_tokens
                    for r in reqs[: len(requests)]
                ):
                    break
        for r in requests:
            r.done = True
        return requests
