"""repro.serve — serving substrate + online-adaptation tier.

* :mod:`repro.serve.engine` — prefill + batched greedy decode engine
  (jax).
* :mod:`repro.serve.adapt` — the continuously-adapting schedule
  selection tier (bounded decision cache, background re-fit,
  exploration-budget measured tier); numpy-only import graph.

Submodules export lazily (PEP 562) so importing the package — or just
the adaptation tier — never pulls jax in.
"""

from __future__ import annotations

_LAZY = {"engine", "adapt"}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return importlib.import_module(f"repro.serve.{name}")
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")


__all__ = ["engine", "adapt"]
