"""Checkpointing: flat-leaf npz + JSON treedef, atomic, restartable."""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(directory: str, state: Any, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(state)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves)}
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **{f"leaf_{i}": l for i, l in enumerate(leaves)})
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(directory, "latest"), "w") as f:
        f.write(str(step))
    return path


def latest_step(directory: str) -> int | None:
    p = os.path.join(directory, "latest")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(directory: str, like: Any, step: int | None = None):
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    leaves, treedef = jax.tree.flatten(like)
    restored = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}"
            )
        restored.append(jnp.asarray(arr, ref.dtype))
    return jax.tree.unflatten(treedef, restored), step
