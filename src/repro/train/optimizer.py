"""AdamW + cosine schedule, pure pytree implementation (no optax dep).

Optimizer state is sharded like the parameters (spec pytree mirrors the
model's param specs), so m/v never blow a device's memory at 512-way SPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # bf16 moments for >100B models keeps optimizer state within HBM at
    # 256-512 chips (quantized-Adam style).
    moment_dtype: str = "float32"


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1 + jnp.cos(jnp.pi * t)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params, moment_dtype: str = "float32") -> dict[str, Any]:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs) -> dict[str, Any]:
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step with global-norm clipping.  Returns (params, state,
    metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(mdt)
        v = (
            cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        ).astype(mdt)
        mh = m.astype(jnp.float32) / bc1
        vh = v.astype(jnp.float32) / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )
