"""Training substrate: jitted train_step + loop with logging/checkpoints."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.models.model import Model, build_model
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.parallel.context import overlap_context
from repro.train import optimizer as opt


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state}


def make_train_step(
    model: Model,
    ocfg: opt.OptimizerConfig,
    *,
    accum_steps: int = 1,
) -> Callable:
    """(state_tree, batch) -> (state_tree, metrics); jit-ready.

    ``accum_steps`` > 1 enables gradient-accumulation microbatching: the
    global batch is split on its leading dim and scanned, cutting live
    activation memory ~accum_steps-fold for one extra grad buffer — the
    "microbatch size" lever of the §Perf candidate list.
    """

    def loss_fn(params, batch):
        with overlap_context(model.config.overlap):
            return model.loss(params, batch)

    def train_step(state, batch):
        if accum_steps == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state["params"], batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(
                    accum_steps, a.shape[0] // accum_steps, *a.shape[1:]
                ),
                batch,
            )

            def acc(carry, mb):
                g_sum, l_sum, ce_sum, aux_sum = carry
                (l, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(state["params"], mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (
                    g_sum, l_sum + l, ce_sum + parts["ce"],
                    aux_sum + parts["aux"],
                ), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc,
                (zeros, jnp.float32(0), jnp.float32(0), jnp.float32(0)),
                micro,
            )
            k = 1.0 / accum_steps
            grads = jax.tree.map(lambda g: g * k, grads)
            loss, parts = loss * k, {"ce": ce * k, "aux": aux * k}
        params, opt_state, om = opt.apply_updates(
            state["params"], grads, state["opt_state"], ocfg
        )
        metrics = {
            "loss": loss, "ce": parts["ce"], "aux": parts["aux"], **om
        }
        return {"params": params, "opt_state": opt_state}, metrics

    return train_step


def init_train_state(model: Model, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt_state": opt.init_state(params)}


def train(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    steps: int = 50,
    seed: int = 0,
    ocfg: Optional[opt.OptimizerConfig] = None,
    log_every: int = 10,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    log_fn=print,
) -> dict:
    """Single-host training loop (CPU-scale; the cluster path goes through
    launch/train.py with pjit shardings)."""
    ocfg = ocfg or opt.OptimizerConfig(
        warmup_steps=max(steps // 20, 5), decay_steps=steps
    )
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(model, ocfg))
    data = make_pipeline(cfg, shape, seed=seed)

    history = []
    reg = _metrics.get_metrics()
    t0 = time.time()
    for step, batch in zip(range(steps), data):
        with _trace.span("train/step", "train", step=step):
            state, metrics = step_fn(state, batch)
        reg.counter("train/steps").inc()
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.time() - t0
            history.append(m)
            log_fn(
                f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}"
            )
        if checkpoint_dir and checkpoint_every and (
            step % checkpoint_every == checkpoint_every - 1
        ):
            from repro.ckpt.checkpoint import save_checkpoint

            save_checkpoint(checkpoint_dir, state, step)
    return {"state": state, "history": history, "model": model}
