"""Cross-version JAX compatibility helpers.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to a top-level API (kwarg
``check_vma``), ``lax.axis_size`` is new-JAX-only (the old idiom is the
constant-folded ``lax.psum(1, axis)``), and the Pallas TPU surface renamed
``TPUCompilerParams`` -> ``CompilerParams`` while growing the dedicated
Mosaic interpreter (``InterpretParams``).  Everything in this repo routes
through these helpers so both old and new JAX releases work unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
from jax import lax
from jax.experimental.pallas import tpu as pltpu

# The Mosaic TPU interpreter and the MESH-tuple device-id convention for
# remote DMAs arrived together; its presence gates both code paths.
_NEW_PALLAS = hasattr(pltpu, "InterpretParams")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-agnostic ``shard_map`` with the new-style keyword API.

    ``check_vma`` defaults to True like ``jax.shard_map`` itself; call
    sites that wrap Pallas DMA kernels (whose outputs the checker cannot
    reason about) pass ``check_vma=False`` explicitly.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name: str) -> int:
    """Static size of a shard_map/pmap axis, on any JAX version."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)  # constant-folds to a Python int


def tpu_interpret(interpret: bool):
    """``interpret=`` argument for a DMA-using TPU pallas_call.

    New JAX: the Mosaic interpreter (simulates cross-device DMAs +
    semaphores, including the race detector).  Old JAX: the generic pallas
    interpreter, whose state-discharge rules also model remote DMAs.
    """
    if not interpret:
        return False
    return pltpu.InterpretParams() if _NEW_PALLAS else True


def tpu_compiler_params(**kwargs):
    """Build CompilerParams/TPUCompilerParams, dropping unknown fields."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in kwargs.items() if k in fields})


def remote_device_id(peer):
    """(device_id, device_id_type) pair for remote DMAs / semaphore signals.

    New JAX expects a mesh coordinate tuple; the old interpreter's
    discharge rules require a scalar logical id (identical on the 1-D
    overlap meshes used throughout this repo).
    """
    if _NEW_PALLAS:
        return (peer,), pltpu.DeviceIdType.MESH
    return peer, pltpu.DeviceIdType.LOGICAL


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New JAX: ``jax.sharding.set_mesh``.  Old JAX: ``Mesh`` is itself a
    context manager that installs the physical mesh our sharding helpers
    fall back to (``pxla.thread_resources``).
    """
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def remote_semaphore_signal(sem_ref, inc, peer):
    """Signal a semaphore on a peer device (slot flow control).

    The old generic interpreter has no remote-signal discharge rule.  Its
    ``dma_start`` discharge executes every exchange as a lockstep
    collective, so devices cannot run ahead of each other and a *local*
    signal keeps the semaphore arithmetic identical without weakening the
    simulated schedule.  Real TPUs and the Mosaic interpreter use the true
    remote signal.
    """
    if _NEW_PALLAS:
        pltpu.semaphore_signal(
            sem_ref,
            inc,
            device_id=peer,
            device_id_type=pltpu.DeviceIdType.MESH,
        )
    else:
        pltpu.semaphore_signal(sem_ref, inc)


__all__ = [
    "shard_map",
    "axis_size",
    "tpu_interpret",
    "tpu_compiler_params",
    "remote_device_id",
    "remote_semaphore_signal",
    "set_mesh",
]
