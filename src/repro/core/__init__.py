"""FiCCO core: the paper's contribution as a composable library.

Layers:
  * machine / workload  — hardware + operator descriptors (Table I included)
  * inefficiency        — DIL / CIL analytic models (§IV), paper-calibrated
  * schedule_types      — the design space (Fig. 11a)
  * simulator           — two-channel discrete schedule simulator (Fig. 11b)
  * engine              — unified Engine protocol + backend registry
  * batch               — NumPy-vectorized batched grid engine (S x M x L)
  * heuristics          — static OTB x MT schedule selection (Fig. 12a)
  * explorer            — full design-space exploration + pruning argument

Sweeping a design space takes three lines::

    from repro.core import TABLE_I, MI300X, TPU_V5E, explore_grid
    ex = explore_grid(TABLE_I, machines=[MI300X, TPU_V5E])
    print(ex.summary())   # accuracy + losses over all schedules at once

and scales to thousands of scenarios (``workload.scenario_grid`` x
``workload.machine_grid``) at >=50x the scalar simulator's throughput
(``benchmarks/bench_sweep.py`` tracks the ratio).
"""

from repro.core.machine import (
    MACHINES,
    MI300X,
    TPU_V5E,
    MachineSpec,
    Topology,
    machine_for_group,
)
from repro.core.workload import (
    SCENARIOS,
    TABLE_I,
    CollectiveKind,
    GemmShape,
    RaggedScenario,
    Scenario,
    StepProfile,
    geomean,
    machine_grid,
    ragged_scenario_grid,
    scenario_grid,
    synthetic_scenarios,
)
from repro.core.schedule_types import (
    ALL_VARIANTS,
    SIGNATURES,
    STUDIED,
    CommShape,
    FiccoVariant,
    Granularity,
    Schedule,
    Uniformity,
)
from repro.core.inefficiency import (
    GemmExec,
    a2a_chunk_step_time,
    ag_serial_time,
    comm_cil,
    gemm_cil,
    gemm_dil,
    gemm_exec,
    gemm_time_decomposed,
    p2p_step_time,
)
from repro.core.simulator import SimResult, best_schedule, simulate
from repro.core.engine import (
    GRID_SCHEDULES,
    Engine,
    GridResult,
    JaxEngine,
    NumpyEngine,
    ScalarEngine,
    engine_names,
    get_engine,
    register_engine,
)
from repro.core.batch import (
    RaggedBatch,
    ScenarioBatch,
    evaluate_grid,
    evaluate_ragged_grid,
)
from repro.core.heuristics import (
    HeuristicDecision,
    calibrate_serial_gate,
    calibrate_tau,
    machine_serial_gate,
    machine_threshold,
    select_schedule,
    select_schedule_batch,
    serial_gate_score,
    serial_gate_score_batch,
    serial_gate_terms_batch,
)
from repro.core.explorer import (
    Exploration,
    GridExploration,
    explore,
    explore_grid,
    prune_report,
)

__all__ = [
    "MACHINES", "MI300X", "TPU_V5E", "MachineSpec", "Topology",
    "machine_for_group",
    "SCENARIOS", "TABLE_I", "CollectiveKind", "GemmShape", "RaggedScenario",
    "Scenario", "StepProfile",
    "geomean", "machine_grid", "ragged_scenario_grid", "scenario_grid",
    "synthetic_scenarios",
    "ALL_VARIANTS", "SIGNATURES", "STUDIED", "CommShape", "FiccoVariant",
    "Granularity", "Schedule", "Uniformity",
    "GemmExec", "a2a_chunk_step_time", "ag_serial_time", "comm_cil",
    "gemm_cil", "gemm_dil", "gemm_exec", "gemm_time_decomposed",
    "p2p_step_time",
    "SimResult", "best_schedule", "simulate",
    "GRID_SCHEDULES", "GridResult", "RaggedBatch", "ScenarioBatch",
    "evaluate_grid", "evaluate_ragged_grid",
    "Engine", "ScalarEngine", "NumpyEngine", "JaxEngine",
    "engine_names", "get_engine", "register_engine",
    "HeuristicDecision", "calibrate_serial_gate", "calibrate_tau",
    "machine_serial_gate", "machine_threshold",
    "select_schedule", "select_schedule_batch",
    "serial_gate_score", "serial_gate_score_batch",
    "serial_gate_terms_batch",
    "Exploration", "GridExploration", "explore", "explore_grid",
    "prune_report",
]
