"""FiCCO schedule-selection heuristics (paper Fig. 12a).

The decision tree uses only *static* GEMM parameters so frameworks/runtimes
can pick a bespoke schedule without profiling:

  1. Communication shape: 1D if M > K else 2D — minimizes the dominant DIL
     direction (row-sharding hurts when M < K, §IV-C1).  2D has a single
     studied schedule: uniform-fused-2D.
  2. Within 1D, compare the combined OTB x MT metric (note OTB * MT_bytes
     == 2*M*N*K == the GEMM's FLOPs) against a machine-level threshold
     derived from peak compute (op-to-byte x memory bandwidth = FLOPs,
     scaled by a one-time-tuned horizon TAU):

        metric <  T        -> uniform-fused-1D   (low DIL / high CIL)
        metric >= 5 * T    -> hetero-unfused-1D  (high DIL / low CIL)
        otherwise          -> hetero-fused-1D    (balanced)

TAU is the paper's "one-time tuning cost for thresholds" (§VIII-C); it is
fit once per machine in ``calibrate_tau`` against the simulator and then
frozen (default below was frozen for MI300X).

Beyond the paper, the tree carries a **serial gate** learned from the
PR-1 design-space grid: the paper's tree always decomposes, but at grid
scale ~65% of (scenario, machine) points have a *serial* analytic
optimum — comm-bound operators whose finer-grain exchange inflates the
dominant communication stream (per-chunk latency + ramp, comm CIL) by
more than the compute it hides.  The static signal is

    score = r * (inflate * CIL - 1),   r = T_comm / T_gemm (roofline),
    inflate = chunked/serial all-gather time from the link model,

"serial wins" iff the inflated comm overhead exceeds the hidden compute,
i.e. score > gate with gate ~= 1 (the frozen default is calibrated on
the grid, see ``calibrate_serial_gate``).  This closes the grid-wide
within-5% gap from ~30% to ~80% while leaving every overlap-profitable
Table-I pick untouched.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.machine import MachineSpec
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape

# One-time tuned horizon (seconds of peak compute) per machine family —
# frozen after calibration against the schedule simulator (paper §VIII-C:
# thresholds carry a one-time tuning cost per machine).
DEFAULT_TAU = 0.02
_TAU_OVERRIDES: dict[str, float] = {}

# Beyond-paper guard: operators too small to amortize even one extra kernel
# launch per chunk are left serial (the paper's scenarios never hit this; our
# smoke-scale models do).
MIN_DECOMPOSE_FLOPS = 1.0e9

# Serial/overlap gate (see module docstring): stay serial when
# ``serial_gate_score > gate``.  The theory-derived breakeven is 1.0;
# the frozen default is calibrated on the PR-1 scenario-grid x
# machine-grid sweep, constrained to keep the paper-fidelity sets
# (Table I + 16 synthetic, MI300X) at their pre-gate accuracy.
DEFAULT_SERIAL_GATE = 1.2
_SERIAL_GATE_OVERRIDES: dict[str, float] = {}
# FiCCO comm CIL geomean (paper §IV-D) used inside the gate score.
_GATE_COMM_CIL = 1.12


def machine_serial_gate(machine: MachineSpec) -> float:
    """The hand-tuned scalar gate threshold for a machine.

    This is the *scalar* end of the gate resolution:
    ``select_schedule`` consults a learned per-machine-family gate
    (:func:`repro.learn.gate.set_machine_gate`) ahead of this value —
    see :func:`_family_gate` — so this threshold applies only when no
    learned family covers the machine.
    """
    return _SERIAL_GATE_OVERRIDES.get(machine.name, DEFAULT_SERIAL_GATE)


def _family_gate(machine: MachineSpec):
    """Learned family gate for a machine, or None.

    Soft lookup through ``sys.modules``: the core package never imports
    :mod:`repro.learn` (which would drag numpy-only deployments through
    the training stack), so family gates only steer decisions in
    processes that already loaded the learn package and registered one.
    """
    import sys

    mod = sys.modules.get("repro.learn.gate")
    if mod is None:
        return None
    try:
        return mod.get_machine_gate(machine)
    except Exception:
        return None


def serial_gate_terms_batch(m, n, k, dtype_bytes, machine: MachineSpec):
    """Vectorized ``(r, inflate)`` terms of the serial-gate score.

    All quantities are static machine-model numbers (no profiling):
    ``r`` compares the serial all-gather against the peak-rate
    per-device GEMM; ``inflate`` is the chunked/serial all-gather time
    ratio from the shared link model (g FiCCO steps of 1/g^2-sized
    chunks vs one serial all-gather — both via the same
    ``repro.core.batch`` formulas the engines use, so a comm-model fix
    propagates here automatically).  ``repro.learn.features`` reuses
    these terms as learned-gate inputs, so the heuristic and the
    learner can never drift apart on their definitions.
    """
    from repro.core import batch as _batch  # local: avoids a cycle

    m = np.asarray(m, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    b = np.asarray(dtype_bytes, dtype=np.float64)
    g = machine.group
    dev_n = np.where(n % g == 0, n / g, n)
    mk_bytes = m * k * b
    t_comm = mk_bytes / machine.ag_bw
    t_gemm = 2.0 * m * dev_n * k / machine.peak_flops
    with np.errstate(divide="ignore", invalid="ignore"):
        r = t_comm / t_gemm
        t_serial_ag = _batch.ag_serial_time_vec(mk_bytes, machine)
        t_chunked_ag = g * _batch.a2a_chunk_step_time_vec(
            mk_bytes / (g * g), machine
        )
        inflate = t_chunked_ag / t_serial_ag
    return r, inflate


def serial_gate_score_from_terms(r, inflate):
    """Gate score from precomputed :func:`serial_gate_terms_batch` terms
    (lets callers that also need the terms compute them once)."""
    with np.errstate(invalid="ignore"):
        return r * (inflate * _GATE_COMM_CIL - 1.0)


def serial_gate_score_batch(m, n, k, dtype_bytes, machine: MachineSpec):
    """Vectorized gate score: comm/compute ratio x net chunking overhead.

    Overlap can hide at most the GEMM; chunking costs
    ``(inflate * CIL - 1)`` of the comm — serial wins when the latter
    (scaled by r) exceeds 1.  See :func:`serial_gate_terms_batch` for
    the two terms.
    """
    return serial_gate_score_from_terms(
        *serial_gate_terms_batch(m, n, k, dtype_bytes, machine)
    )


def serial_gate_score(gemm: GemmShape, machine: MachineSpec) -> float:
    return float(
        serial_gate_score_batch(
            gemm.m, gemm.n, gemm.k, gemm.dtype_bytes, machine
        )
    )


def calibrate_serial_gate(
    machines,
    scenarios,
    candidates=(0.3, 0.5, 0.7, 0.9, 1.0, 1.2, 1.5, 2.0, 3.0),
    *,
    freeze: bool = False,
    backend: str = "numpy",
) -> float:
    """Learn the serial/overlap gate from a grid: pick the candidate that
    maximizes grid-wide within-5% accuracy of the gated heuristic.

    One batched sweep supplies the analytic optima; every candidate is a
    vectorized re-gating.  ``freeze=True`` records the winner as a
    per-machine override for each machine in ``machines``.  ``backend``
    names any registered engine (``repro.core.engine``); the jitted
    ``"jax"`` engine pays off on large calibration grids.
    """
    from repro.core import batch as _batch  # local: avoids a cycle
    from repro.core.engine import get_engine

    machines = tuple(machines)
    sb = _batch.ScenarioBatch.from_scenarios(scenarios)
    grid = get_engine(backend).evaluate(sb, machines)
    best_total = grid.best_total()
    s_idx = np.arange(len(sb))[:, None]
    m_idx = np.arange(len(machines))[None, :]
    base_picks = np.stack(
        [
            select_schedule_batch(
                sb.m, sb.n, sb.k, sb.dtype_bytes, mach, serial_gate=np.inf
            )
            for mach in machines
        ],
        axis=1,
    )
    scores = np.stack(
        [
            serial_gate_score_batch(sb.m, sb.n, sb.k, sb.dtype_bytes, mach)
            for mach in machines
        ],
        axis=1,
    )
    serial_l = _batch.SCHEDULE_INDEX[Schedule.SERIAL]

    best_gate, best_acc = candidates[0], -1.0
    for gate in candidates:
        picks = np.where(scores > gate, serial_l, base_picks)
        t = grid.total[picks, s_idx, m_idx]
        acc = float(
            np.mean(np.nan_to_num(t, nan=np.inf) <= 1.05 * best_total)
        )
        if acc > best_acc:
            best_gate, best_acc = gate, acc
    if freeze:
        for mach in machines:
            _SERIAL_GATE_OVERRIDES[mach.name] = best_gate
    return best_gate


@dataclasses.dataclass(frozen=True)
class HeuristicDecision:
    schedule: Schedule
    metric: float  # OTB x MT == GEMM FLOPs
    threshold: float
    reason: str


def machine_threshold(machine: MachineSpec, tau: float | None = None) -> float:
    """T = peak FLOP/s x TAU: 'op-to-byte x memory bandwidth = FLOPs'."""
    if tau is None:
        tau = _TAU_OVERRIDES.get(machine.name, DEFAULT_TAU)
    return machine.peak_flops * tau


def select_schedule(
    gemm: GemmShape,
    machine: MachineSpec,
    *,
    tau: float | None = None,
    allow_serial_guard: bool = True,
    serial_gate: float | None = None,
    profile=None,
    gate=None,
) -> HeuristicDecision:
    """Static schedule pick (Fig. 12a tree + the learned serial gate).

    ``serial_gate`` overrides the calibrated gate threshold; pass
    ``float("inf")`` to disable the gate (the paper's original tree).
    The gate only applies when ``allow_serial_guard`` is True — both are
    "stay serial" escapes the paper does not model.

    ``profile`` (a :class:`~repro.core.workload.StepProfile`) makes the
    gate **skew-aware**: a ragged decomposition's largest chunk sets the
    pipeline's critical step, so the chunking-overhead score is scaled
    by the profile's imbalance (max/mean active-step share) — heavily
    skewed EP dispatches fall back to serial sooner, which is exactly
    what the ragged grid's analytic optima show.

    ``gate`` (a :class:`repro.learn.gate.LearnedGate`) replaces the
    scalar threshold with the sweep-learned threshold *family*: the raw
    gate score is compared against a per-scenario threshold conditioned
    on ``(imbalance, active_steps, OTB, r)`` — the profile's skew enters
    as a tree feature rather than a fixed multiplicative scaling.  It
    takes precedence over both the calibrated per-machine gate and an
    explicit ``serial_gate`` float.
    """
    metric = gemm.otb * gemm.bytes_mt  # == gemm.flops
    t = machine_threshold(machine, tau)

    if allow_serial_guard and gemm.flops < MIN_DECOMPOSE_FLOPS:
        return HeuristicDecision(
            Schedule.SERIAL, metric, t,
            "operator too small to amortize decomposition (beyond-paper guard)",
        )
    if allow_serial_guard:
        score = serial_gate_score(gemm, machine)
        if gate is None and serial_gate is None:
            # Neither an explicit learned gate nor an explicit scalar:
            # a registered per-machine-family gate outranks the
            # hand-tuned scalar below.
            gate = _family_gate(machine)
        if gate is not None:
            # ``>=`` matches the learned gate's training accounting
            # (score bins are right-closed at the threshold edges).
            thr = float(gate.threshold_for(gemm, machine, profile=profile))
            stay_serial = score >= thr
            reason = (
                "comm-bound: chunking overhead exceeds hidden compute "
                "(sweep-learned gate family)"
            )
        else:
            g_thr = (
                serial_gate
                if serial_gate is not None
                else machine_serial_gate(machine)
            )
            imbalance = 1.0 if profile is None else float(profile.imbalance)
            stay_serial = score * imbalance > g_thr
            reason = (
                "comm-bound: chunking overhead exceeds hidden compute "
                "(grid-learned serial gate)"
            )
        if stay_serial:
            return HeuristicDecision(Schedule.SERIAL, metric, t, reason)
    if gemm.m < gemm.k:
        return HeuristicDecision(
            Schedule.UNIFORM_FUSED_2D, metric, t,
            "M < K: row-sharding suboptimal -> 2D (column) communication",
        )
    if metric < t:
        return HeuristicDecision(
            Schedule.UNIFORM_FUSED_1D, metric, t,
            "OTBxMT below machine threshold: DIL-sensitive, CIL-tolerant",
        )
    if metric >= 5.0 * t:
        return HeuristicDecision(
            Schedule.HETERO_UNFUSED_1D, metric, t,
            "OTBxMT >= 5x threshold: CIL-sensitive, DIL-tolerant",
        )
    return HeuristicDecision(
        Schedule.HETERO_FUSED_1D, metric, t,
        "OTBxMT in middle tranche: balanced signature",
    )


def select_schedule_batch(
    m,
    n,
    k,
    dtype_bytes,
    machine: MachineSpec,
    *,
    tau: float | None = None,
    allow_serial_guard: bool = True,
    serial_gate: float | None = None,
    imbalance=None,
    active_steps=None,
    gate=None,
    terms=None,
):
    """Vectorized :func:`select_schedule` over ``(S,)`` shape arrays.

    Returns an int array of indices into ``repro.core.batch.GRID_SCHEDULES``
    (the same order the batched simulator uses), replicating the scalar
    decision tree branch for branch.

    ``imbalance`` is the per-scenario ragged-profile imbalance factor
    (``RaggedBatch.imbalance``; 1.0 == uniform): it scales the serial
    gate score exactly like the scalar tree's ``profile`` argument.

    ``gate`` (a :class:`repro.learn.gate.LearnedGate`) swaps the scalar
    gate for the learned threshold family, exactly like the scalar
    tree's ``gate`` argument; ``active_steps`` (per-scenario active step
    counts, default ``machine.group``) is a gate feature alongside
    ``imbalance``.  ``terms`` optionally carries precomputed
    :func:`serial_gate_terms_batch` output so batch callers evaluate the
    link model exactly once.
    """
    from repro.core.batch import SCHEDULE_INDEX  # local: avoids a cycle

    m = np.asarray(m)
    n = np.asarray(n)
    k = np.asarray(k)
    b = np.asarray(dtype_bytes)
    flops = 2.0 * m * n * k
    bytes_mt = (m * k + k * n + m * n).astype(np.float64) * b
    metric = (flops / bytes_mt) * bytes_mt  # == flops, scalar-model order
    t = machine_threshold(machine, tau)

    if allow_serial_guard:
        if terms is None:
            terms = serial_gate_terms_batch(m, n, k, b, machine)
        scores = serial_gate_score_from_terms(*terms)
        if gate is None and serial_gate is None:
            # Same family-gate precedence as the scalar tree.
            gate = _family_gate(machine)
        if gate is not None:
            # ``>=`` matches the learned gate's training accounting.
            # The precomputed terms ride along so the gate's feature
            # matrix does not recompute the link model.
            thr = gate.thresholds_batch(
                m, n, k, b, machine,
                imbalance=imbalance, active_steps=active_steps,
                terms=terms,
            )
            stay_serial = (flops < MIN_DECOMPOSE_FLOPS) | (scores >= thr)
        else:
            g_thr = (
                serial_gate
                if serial_gate is not None
                else machine_serial_gate(machine)
            )
            imb = (
                1.0 if imbalance is None
                else np.asarray(imbalance, np.float64)
            )
            stay_serial = (flops < MIN_DECOMPOSE_FLOPS) | (
                scores * imb > g_thr
            )
    else:
        stay_serial = np.zeros(m.shape, dtype=bool)
    conds = [
        stay_serial,
        m < k,
        metric < t,
        metric >= 5.0 * t,
    ]
    choices = [
        SCHEDULE_INDEX[Schedule.SERIAL],
        SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_2D],
        SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_1D],
        SCHEDULE_INDEX[Schedule.HETERO_UNFUSED_1D],
    ]
    return np.select(conds, choices, SCHEDULE_INDEX[Schedule.HETERO_FUSED_1D])


def calibrate_tau(
    machine: MachineSpec,
    scenarios,
    candidates=(0.02, 0.05, 0.1, 0.2, 0.5, 1.0),
    *,
    backend: str = "numpy",
) -> float:
    """One-time TAU fit: maximize agreement with the simulator-optimal
    schedule over a calibration set (paper tunes thresholds per machine).

    Runs as one batched sweep: the simulator-optimal schedules come from
    a single engine evaluation (``backend`` names any registered engine)
    and each TAU candidate is a vectorized re-threshold — no
    per-(tau, scenario) scalar simulation.
    """
    from repro.core import batch as _batch  # local: avoids a cycle
    from repro.core.engine import get_engine

    sb = _batch.ScenarioBatch.from_scenarios(scenarios)
    grid = get_engine(backend).evaluate(sb, (machine,))
    best = grid.best_idx()[:, 0]

    best_tau, best_acc = candidates[0], -1.0
    for tau in candidates:
        picks = select_schedule_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, machine, tau=tau
        )
        acc = float(np.mean(picks == best))
        if acc > best_acc:
            best_tau, best_acc = tau, acc
    _TAU_OVERRIDES[machine.name] = best_tau
    return best_tau
