"""FiCCO schedule-selection heuristics (paper Fig. 12a).

The decision tree uses only *static* GEMM parameters so frameworks/runtimes
can pick a bespoke schedule without profiling:

  1. Communication shape: 1D if M > K else 2D — minimizes the dominant DIL
     direction (row-sharding hurts when M < K, §IV-C1).  2D has a single
     studied schedule: uniform-fused-2D.
  2. Within 1D, compare the combined OTB x MT metric (note OTB * MT_bytes
     == 2*M*N*K == the GEMM's FLOPs) against a machine-level threshold
     derived from peak compute (op-to-byte x memory bandwidth = FLOPs,
     scaled by a one-time-tuned horizon TAU):

        metric <  T        -> uniform-fused-1D   (low DIL / high CIL)
        metric >= 5 * T    -> hetero-unfused-1D  (high DIL / low CIL)
        otherwise          -> hetero-fused-1D    (balanced)

TAU is the paper's "one-time tuning cost for thresholds" (§VIII-C); it is
fit once per machine in ``calibrate_tau`` against the simulator and then
frozen (default below was frozen for MI300X).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.machine import MachineSpec
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape

# One-time tuned horizon (seconds of peak compute) per machine family —
# frozen after calibration against the schedule simulator (paper §VIII-C:
# thresholds carry a one-time tuning cost per machine).
DEFAULT_TAU = 0.02
_TAU_OVERRIDES: dict[str, float] = {}

# Beyond-paper guard: operators too small to amortize even one extra kernel
# launch per chunk are left serial (the paper's scenarios never hit this; our
# smoke-scale models do).
MIN_DECOMPOSE_FLOPS = 1.0e9


@dataclasses.dataclass(frozen=True)
class HeuristicDecision:
    schedule: Schedule
    metric: float  # OTB x MT == GEMM FLOPs
    threshold: float
    reason: str


def machine_threshold(machine: MachineSpec, tau: float | None = None) -> float:
    """T = peak FLOP/s x TAU: 'op-to-byte x memory bandwidth = FLOPs'."""
    if tau is None:
        tau = _TAU_OVERRIDES.get(machine.name, DEFAULT_TAU)
    return machine.peak_flops * tau


def select_schedule(
    gemm: GemmShape,
    machine: MachineSpec,
    *,
    tau: float | None = None,
    allow_serial_guard: bool = True,
) -> HeuristicDecision:
    metric = gemm.otb * gemm.bytes_mt  # == gemm.flops
    t = machine_threshold(machine, tau)

    if allow_serial_guard and gemm.flops < MIN_DECOMPOSE_FLOPS:
        return HeuristicDecision(
            Schedule.SERIAL, metric, t,
            "operator too small to amortize decomposition (beyond-paper guard)",
        )
    if gemm.m < gemm.k:
        return HeuristicDecision(
            Schedule.UNIFORM_FUSED_2D, metric, t,
            "M < K: row-sharding suboptimal -> 2D (column) communication",
        )
    if metric < t:
        return HeuristicDecision(
            Schedule.UNIFORM_FUSED_1D, metric, t,
            "OTBxMT below machine threshold: DIL-sensitive, CIL-tolerant",
        )
    if metric >= 5.0 * t:
        return HeuristicDecision(
            Schedule.HETERO_UNFUSED_1D, metric, t,
            "OTBxMT >= 5x threshold: CIL-sensitive, DIL-tolerant",
        )
    return HeuristicDecision(
        Schedule.HETERO_FUSED_1D, metric, t,
        "OTBxMT in middle tranche: balanced signature",
    )


def select_schedule_batch(
    m,
    n,
    k,
    dtype_bytes,
    machine: MachineSpec,
    *,
    tau: float | None = None,
    allow_serial_guard: bool = True,
):
    """Vectorized :func:`select_schedule` over ``(S,)`` shape arrays.

    Returns an int array of indices into ``repro.core.batch.GRID_SCHEDULES``
    (the same order the batched simulator uses), replicating the scalar
    decision tree branch for branch.
    """
    from repro.core.batch import SCHEDULE_INDEX  # local: avoids a cycle

    m = np.asarray(m)
    n = np.asarray(n)
    k = np.asarray(k)
    b = np.asarray(dtype_bytes)
    flops = 2.0 * m * n * k
    bytes_mt = (m * k + k * n + m * n).astype(np.float64) * b
    metric = (flops / bytes_mt) * bytes_mt  # == flops, scalar-model order
    t = machine_threshold(machine, tau)

    conds = [
        (flops < MIN_DECOMPOSE_FLOPS)
        if allow_serial_guard
        else np.zeros(m.shape, dtype=bool),
        m < k,
        metric < t,
        metric >= 5.0 * t,
    ]
    choices = [
        SCHEDULE_INDEX[Schedule.SERIAL],
        SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_2D],
        SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_1D],
        SCHEDULE_INDEX[Schedule.HETERO_UNFUSED_1D],
    ]
    return np.select(conds, choices, SCHEDULE_INDEX[Schedule.HETERO_FUSED_1D])


def calibrate_tau(
    machine: MachineSpec,
    scenarios,
    candidates=(0.02, 0.05, 0.1, 0.2, 0.5, 1.0),
) -> float:
    """One-time TAU fit: maximize agreement with the simulator-optimal
    schedule over a calibration set (paper tunes thresholds per machine).

    Runs as one batched sweep: the simulator-optimal schedules come from a
    single ``evaluate_grid`` call and each TAU candidate is a vectorized
    re-threshold — no per-(tau, scenario) scalar simulation.
    """
    from repro.core import batch as _batch  # local: avoids a cycle

    sb = _batch.ScenarioBatch.from_scenarios(scenarios)
    grid = _batch.evaluate_grid(sb, (machine,))
    best = grid.best_idx()[:, 0]

    best_tau, best_acc = candidates[0], -1.0
    for tau in candidates:
        picks = select_schedule_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, machine, tau=tau
        )
        acc = float(np.mean(picks == best))
        if acc > best_acc:
            best_tau, best_acc = tau, acc
    _TAU_OVERRIDES[machine.name] = best_tau
    return best_tau
