"""Machine models for FiCCO cost analysis.

The paper characterizes an 8x AMD MI300X node with a fully-connected
Infinity-Fabric topology.  Our deployment target is a TPU v5e pod slice whose
``model`` mesh axis is one dimension of the ICI torus.  Both are described by
the same :class:`MachineSpec` so the cost model, simulator, heuristics and
benchmarks can be instantiated for either.

Topology matters for the paper's central claim: on a *full mesh*, ring-style
peer-to-peer shard streaming uses one of ``n-1`` links per step, while a
chunk-level all-to-all uses all of them.  On a *torus ring*, P2P ring steps
are already bandwidth-optimal, and FiCCO's benefit shifts to finer pipeline
granularity and all-to-all asymmetry hiding (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import enum


class Topology(enum.Enum):
    """Interconnect topology of one overlap group."""

    FULL_MESH = "full_mesh"  # MI300X: every pair directly connected.
    TORUS_RING = "torus_ring"  # one axis of a TPU ICI torus (wrap-around).
    SWITCH = "switch"  # NVSwitch-like: flexible point-to-point bandwidth.


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Static hardware description for one device + its overlap group."""

    name: str
    # Peak dense matmul throughput (FLOP/s) for the benchmark dtype (bf16).
    peak_flops: float
    # HBM bandwidth per device (bytes/s).
    hbm_bw: float
    # Uni-directional bandwidth of one inter-device link (bytes/s).
    link_bw: float
    # Number of devices in the overlap group (TP/EP group size).
    group: int
    topology: Topology
    # Links usable by a single P2P transfer (ring step).
    p2p_links: int
    # Links usable concurrently per device during an all-to-all step.
    a2a_links: int
    # Fixed per-kernel launch/setup latency (s). GPU kernel launch or TPU
    # DMA-descriptor issue. Dominates only for tiny operators.
    kernel_latency: float = 3.0e-6
    # Fixed per-transfer latency (s): DMA setup + fabric hop.
    link_latency: float = 2.0e-6
    # VMEM (TPU) / LLC (GPU) capacity per device, bytes.  Used by kernel
    # block-shape selection, not by the analytic model.
    fast_mem_bytes: int = 128 * 1024 * 1024
    # GEMM execution-grain model: output tiles of tile_mn x tile_mn are
    # distributed over `parallel_units` concurrent execution resources
    # (CUs on MI300X; pipelined MXU tile slots on TPU).  Drives wave
    # quantization / occupancy — the dominant source of GEMM DIL.
    tile_mn: int = 256
    tile_k: int = 256
    parallel_units: int = 304
    # Pipeline fill/drain + cold-cache ramp of one kernel: kernels much
    # shorter than this lose a large fraction of peak (why *unfused*
    # per-chunk GEMMs hurt on small operators).
    kernel_ramp: float = 20.0e-6
    # DMA-engine resource budgets, consumed by the kernel-variant
    # feasibility pruner (repro.tune.prune), not by the analytic model:
    # completion-semaphore slots one kernel may allocate, regular
    # (flow-control) semaphore slots, and the minimum granule one DMA
    # descriptor moves efficiently (transfers must be a whole multiple).
    dma_sem_slots: int = 128
    reg_sem_slots: int = 32
    dma_granule: int = 512

    # ---- derived ------------------------------------------------------
    @property
    def balance_otb(self) -> float:
        """Machine balance point, ops/byte: OTB above this is compute bound."""
        return self.peak_flops / self.hbm_bw

    @property
    def ag_bw(self) -> float:
        """Aggregate egress bandwidth one device can use for an all-gather.

        Full mesh: a device sends its shard to ``n-1`` peers over ``n-1``
        dedicated links concurrently.  Torus ring: collectives are chained
        through 2 neighbour links (both directions).
        """
        if self.topology is Topology.FULL_MESH:
            return self.link_bw * (self.group - 1)
        return self.link_bw * self.a2a_links


# ---------------------------------------------------------------------------
# Paper machine: 8x MI300X, fully-connected Infinity Fabric.
#   - 1307.4 TFLOP/s peak bf16 per GPU, 5.3 TB/s HBM3, 64 GB/s/link uni-dir.
# ---------------------------------------------------------------------------
MI300X = MachineSpec(
    name="mi300x-8",
    peak_flops=1307.4e12,
    hbm_bw=5.3e12,
    link_bw=64e9,
    group=8,
    topology=Topology.FULL_MESH,
    p2p_links=1,
    a2a_links=7,
    fast_mem_bytes=256 * 1024 * 1024,  # LLC (Infinity Cache)
    tile_mn=256,
    tile_k=256,
    parallel_units=304,  # CUs
    kernel_ramp=20.0e-6,
)

# ---------------------------------------------------------------------------
# Deployment target: TPU v5e.  ``model`` axis = 16 devices along one torus
# dimension; wrap-around gives 2 links per device per axis direction pair.
# Constants from the brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
# ---------------------------------------------------------------------------
TPU_V5E = MachineSpec(
    name="tpu-v5e-axis16",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    group=16,
    topology=Topology.TORUS_RING,
    p2p_links=1,
    a2a_links=2,
    kernel_latency=1.0e-6,  # DMA descriptor issue; no host launch on-path.
    link_latency=1.5e-6,
    fast_mem_bytes=128 * 1024 * 1024,  # VMEM
    tile_mn=128,
    tile_k=128,
    parallel_units=8,  # MXU pipeline slots; occupancy matters far less.
    kernel_ramp=2.0e-6,  # systolic fill is short; no cold-start kernels.
)

MACHINES = {m.name: m for m in (MI300X, TPU_V5E)}


def machine_for_group(machine: MachineSpec, group: int) -> MachineSpec:
    """Re-target a machine model at a different overlap-group size.

    On a full mesh the per-device all-to-all link count tracks the group
    (every peer is directly attached); torus link counts are physical
    and stay put.
    """
    if group == machine.group:
        return machine
    a2a = (
        group - 1
        if machine.topology is Topology.FULL_MESH
        else machine.a2a_links
    )
    return dataclasses.replace(machine, group=group, a2a_links=a2a)


def get_machine(name: str) -> MachineSpec:
    try:
        return MACHINES[name]
    except KeyError:
        raise KeyError(f"unknown machine {name!r}; known: {sorted(MACHINES)}")
