"""Analytic DIL / CIL models (paper §IV), calibrated to the paper's data.

Decomposition Inefficiency caused Loss (**DIL**) is *emergent* here rather
than a fudge factor: a decomposed GEMM re-reads the stationary operand once
per chunk, pays a kernel-launch latency per chunk, and loses tile-quantization
efficiency on small dimensions.  Feeding those physical terms through the
device roofline reproduces the paper's observations:

  * row (M) sharding re-reads the (K, N) weight -> hurts when M < K,
  * column (K) sharding re-reads/accumulates the (M, N) output -> hurts when
    M > K,
  * DIL anti-correlates with the GEMM's op-to-byte ratio,
  * 64-way sharding is worse than 8-way.

Contention Inefficiency caused Loss (**CIL**) is modelled as HBM-bandwidth
interference between the concurrent streams: the paper shows CIL grows with
the GEMM's static memory traffic (MT) and with the schedule's concurrency
degree, and that DMA-offloaded communication suffers far less than GPU
core-driven (RCCL) communication.  Coefficients are calibrated (bisection, at
import) so the Table-I geomeans match the paper:

  * GEMM CIL geomean 1.11x (FiCCO, DMA), 1.07x (shard overlap, DMA),
  * comm CIL geomean 1.12x (FiCCO), 1.03x (shard overlap),
  * comm DIL geomean ~1.10x for 8x-smaller all-gathers.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.machine import MachineSpec, Topology
from repro.core.workload import TABLE_I, GemmShape


def _geomean_vec(vals: "np.ndarray") -> float:
    """Vectorized geomean (the calibration bisections' inner loop)."""
    return float(np.exp(np.mean(np.log(vals))))

@dataclasses.dataclass(frozen=True)
class GemmExec:
    """One GEMM kernel's modelled execution (isolated, no contention)."""

    shape: GemmShape
    time: float
    compute_time: float
    memory_time: float
    bytes_hbm: float
    occupancy: float  # useful fraction of the issued compute waves
    splits: int  # split-K factor the kernel had to use to fill the machine

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time >= self.memory_time else "memory"


def gemm_exec(
    shape: GemmShape,
    machine: MachineSpec,
    *,
    accumulate: bool = False,
    hbm_bw_frac: float = 1.0,
) -> GemmExec:
    """Execution time of a single (possibly decomposed) GEMM.

    Model = roofline + execution-grain effects, which is where GEMM DIL
    (paper §IV-C1) physically comes from:

      * **wave quantization / occupancy**: the (M, N) output is tiled into
        ``tile_mn^2`` blocks scheduled over ``parallel_units`` resources.
        Small decomposed GEMMs fill a fraction of one wave.  Production
        libraries (hipblaslt stream-k, split-K) recover occupancy by
        splitting the K reduction — at the price of partial-sum traffic,
        which we charge.
      * **operand re-streaming**: padded tiles and the (K, N) weight /
        (M, N) accumulator traffic feed the memory roofline, so row-sharded
        chunks hurt when M < K and column-sharded (accumulating) chunks
        hurt when M > K, exactly the paper's observed asymmetry.
      * per-kernel launch latency.

    ``accumulate`` adds the C read-modify-write of a `C += A @ B` kernel.
    ``hbm_bw_frac`` is the bandwidth share left under contention.
    """
    m, n, k, b = shape.m, shape.n, shape.k, shape.dtype_bytes
    if m <= 0 or n <= 0 or k <= 0:
        # Degenerate chunk (e.g. hetero schedules with m < group^2):
        # surface the same ValueError contract as GemmShape.shard so
        # callers (and the batched engine's validity mask) see one
        # error type for "this decomposition does not exist".
        raise ValueError(f"degenerate GEMM chunk {shape}")
    t_mn, pu = machine.tile_mn, machine.parallel_units
    tiles = math.ceil(m / t_mn) * math.ceil(n / t_mn)
    # split-K to fill the machine when the chunk has too few output tiles.
    # Real libraries cap the split factor (partial-reduction epilogues stop
    # paying beyond ~8): tiny-output huge-K chunks stay under-occupied,
    # which is exactly the paper's "row-sharding hurts when M < K".
    splits = 1
    if tiles < pu:
        # Chunks with a single output-tile row can barely exploit split-K
        # (partials of one tile row serialize on the epilogue).
        split_cap = 2 if m <= t_mn else 8
        splits = min(
            math.ceil(pu / tiles), max(k // machine.tile_k, 1), split_cap
        )
    work = tiles * splits
    # Padded flops: partially-filled tiles still occupy their unit.
    padded_flops = (
        2.0
        * (math.ceil(m / t_mn) * t_mn)
        * (math.ceil(n / t_mn) * t_mn)
        * k
    )
    # Occupancy: blend hard wave quantization with stream-K-style smoothing
    # (real libraries recover part of, not all of, the tail wave).
    occ_quant = work / (math.ceil(work / pu) * pu)
    occ_smooth = min(1.0, work / pu)
    occupancy = 0.5 * (occ_quant + occ_smooth)
    # Reduction-depth ramp: short K chunks spend a larger fraction of each
    # tile in the MAC-pipeline prologue/epilogue (why accumulating K-sharded
    # chunks lose efficiency when K is cut 8/64-way, paper Fig. 7 right).
    k_eff = k / (k + machine.tile_k)
    compute = padded_flops / machine.peak_flops / max(occupancy * k_eff, 1e-9)

    bytes_hbm = float(m * k + k * n + m * n) * b
    if accumulate:
        bytes_hbm += float(m * n) * b  # read-modify-write of C
    if splits > 1:
        # fp32 partial tiles written + re-read for the reduction epilogue.
        bytes_hbm += 2.0 * (splits - 1) * float(m * n) * 4
    memory = bytes_hbm / (machine.hbm_bw * hbm_bw_frac)
    base = max(compute, memory)
    # Short-kernel ramp: pipeline fill/drain + cold caches take a roughly
    # fixed time slice, so kernels shorter than ~5x the ramp lose a big
    # fraction of peak.
    ramp = machine.kernel_ramp
    t = machine.kernel_latency + base * (1.0 + ramp / (base + ramp))
    return GemmExec(shape, t, compute, memory, bytes_hbm, occupancy, splits)


def gemm_time_decomposed(
    shape: GemmShape,
    machine: MachineSpec,
    ways: int,
    axis: str,
    *,
    hbm_bw_frac: float = 1.0,
) -> float:
    """Aggregate isolated time of ``ways`` chunks (serial on one device)."""
    chunk = shape.shard(ways, axis)
    per = gemm_exec(
        chunk, machine, accumulate=(axis == "k"), hbm_bw_frac=hbm_bw_frac
    )
    return ways * per.time


def gemm_dil(shape: GemmShape, machine: MachineSpec, ways: int, axis: str) -> float:
    """DIL slowdown factor: decomposed aggregate time / monolithic time."""
    base = gemm_exec(shape, machine).time
    return gemm_time_decomposed(shape, machine, ways, axis) / base


# ---------------------------------------------------------------------------
# Communication model.
# ---------------------------------------------------------------------------

# Bandwidth ramp: a transfer of size s achieves bw * s / (s + s_half).  The
# half-saturation size is calibrated below so an 8x smaller all-gather incurs
# the paper's ~10% geomean DIL at Table-I sizes.
_COMM_S_HALF_TARGET_DIL = 1.10


def comm_time(
    nbytes_per_link: float,
    machine: MachineSpec,
    *,
    s_half: float,
    n_transfers: int = 1,
) -> float:
    """Time to push ``nbytes_per_link`` through one link, ``n_transfers``
    sequential DMA descriptors (each pays latency + ramp)."""
    per = nbytes_per_link / max(n_transfers, 1)
    t_one = machine.link_latency + (per + s_half) / machine.link_bw
    return n_transfers * t_one


@functools.lru_cache(maxsize=None)
def calibrated_s_half(machine: MachineSpec) -> float:
    """Solve the ramp size so FiCCO's 8x-finer AG has ~10% geomean DIL.

    The Table-I evaluation inside each bisection step is vectorized: the
    per-scenario link loads are precomputed once and every candidate is a
    handful of array ops, so a cold cache costs microseconds instead of
    re-walking scalar Python 60x16 times (this sits on the batched sweep
    engine's cold path, see ``repro.core.batch``).
    """
    g = machine.group
    shard_per_link = np.array(
        [
            sc.gemm.m * sc.gemm.k * sc.gemm.dtype_bytes
            / g
            / max(machine.a2a_links, 1)
            for sc in TABLE_I
        ],
        dtype=np.float64,
    )
    base = machine.link_latency + shard_per_link / machine.link_bw

    def dil_geomean(s_half: float) -> float:
        fine = g * (
            machine.link_latency
            + (shard_per_link / g + s_half) / machine.link_bw
        )
        return _geomean_vec(fine / base)

    lo, hi = 0.0, 64 * 1024 * 1024
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if dil_geomean(mid) < _COMM_S_HALF_TARGET_DIL:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def ag_serial_time(mk_bytes: float, machine: MachineSpec) -> float:
    """Isolated all-gather of an M-sharded (M, K) buffer (baseline step S1).

    Full mesh: every device sends its shard to g-1 peers over g-1 links in
    parallel -> one shard's worth of time per link.  Torus ring: the shard is
    pipelined around the ring over ``a2a_links`` links; total ingress per
    device is (g-1)/g of the buffer.
    """
    g = machine.group
    shard = mk_bytes / g
    if machine.topology is Topology.FULL_MESH:
        per_link = shard
    else:
        per_link = mk_bytes * (g - 1) / g / machine.a2a_links
    return comm_time(per_link, machine, s_half=calibrated_s_half(machine))


def p2p_step_time(shard_bytes: float, machine: MachineSpec) -> float:
    """One ring step of shard-granularity P2P overlap (AsyncTP style).

    The defining deficiency on a full mesh (paper Fig. 13): the transfer uses
    ONE link; the other g-2 stay idle.  Over g-1 steps the communication takes
    ~(g-1)x the ideal all-gather -> the paper's observed ~7x comm slowdown.
    """
    return comm_time(
        shard_bytes / machine.p2p_links,
        machine,
        s_half=calibrated_s_half(machine),
    )


def a2a_chunk_step_time(chunk_bytes: float, machine: MachineSpec) -> float:
    """One FiCCO step: simultaneously send one chunk to each peer.

    Full mesh: (g-1) chunks leave over (g-1) links -> one chunk per link.
    Torus: the same bytes leave over ``a2a_links`` links.
    """
    g = machine.group
    if machine.topology is Topology.FULL_MESH:
        per_link, n = chunk_bytes, 1
    else:
        per_link = chunk_bytes * (g - 1) / machine.a2a_links
        n = max((g - 1) // machine.a2a_links, 1)
    return comm_time(
        per_link, machine, s_half=calibrated_s_half(machine), n_transfers=n
    )


# ---------------------------------------------------------------------------
# CIL: contention between concurrent streams.
# ---------------------------------------------------------------------------

_CIL_TARGETS = {
    # (metric, concurrency_degree): geomean slowdown from the paper §IV-D.
    ("gemm", 3): 1.11,  # FiCCO, DMA comm
    ("gemm", 2): 1.07,  # shard overlap, DMA comm
    ("comm", 3): 1.12,  # FiCCO
    ("comm", 2): 1.03,  # shard overlap
}
# GPU-core-driven communication (RCCL) additionally steals CUs from the GEMM.
# Paper Fig. 9 shows RCCL CIL far above DMA; there is no TPU analogue (ICI
# transfers are always DMA), we keep it for the paper-fidelity benchmarks.
RCCL_EXTRA_GEMM_CIL = 0.45


@functools.lru_cache(maxsize=None)
def _mt_ref(machine: MachineSpec) -> float:
    """Largest Table-I M-sharded memory traffic (the CIL normalizer)."""
    return max(s.gemm.shard(machine.group, "m").bytes_mt for s in TABLE_I)


def _mt_norm(shape: GemmShape, machine: MachineSpec) -> float:
    """Memory-traffic pressure of the 8-way M-sharded GEMM, normalized to
    the largest Table-I scenario (the paper's CIL x-axis)."""
    return shape.bytes_mt / _mt_ref(machine)


@functools.lru_cache(maxsize=None)
def _cil_coeff(machine: MachineSpec, metric: str, degree: int) -> float:
    """Calibrate `cil = 1 + c * (degree-1) * mt_norm^p` to the paper geomean.

    Vectorized like :func:`calibrated_s_half`: the Table-I pressure terms
    are precomputed as one array and each bisection step is a single
    geomean over it.
    """
    target_key = (metric, min(max(degree, 2), 3))
    target = _CIL_TARGETS[target_key]
    p = 0.5  # sub-linear: big GEMMs saturate contention
    shapes = [s.gemm.shard(machine.group, "m") for s in TABLE_I]
    xs = np.array([_mt_norm(sh, machine) ** p for sh in shapes])
    deg = target_key[1]

    def gm(c: float) -> float:
        return _geomean_vec(1.0 + c * (deg - 1) * xs)

    lo, hi = 0.0, 4.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if gm(mid) < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def gemm_cil(
    shape: GemmShape,
    machine: MachineSpec,
    *,
    degree: int,
    dma: bool = True,
) -> float:
    """Slowdown of a GEMM chunk while communication (+gather/scatter) runs."""
    p = 0.5
    c = _cil_coeff(machine, "gemm", degree)
    cil = 1.0 + c * (min(degree, 3) - 1) * _mt_norm(shape, machine) ** p
    if degree > 3:  # gather+scatter both live adds residual pressure
        cil *= 1.0 + 0.02 * (degree - 3)
    if not dma:
        cil += RCCL_EXTRA_GEMM_CIL * _mt_norm(shape, machine) ** p + 0.15
    return cil


def comm_cil(
    gemm_shape: GemmShape,
    machine: MachineSpec,
    *,
    degree: int,
    dma: bool = True,
) -> float:
    """Slowdown of the communication stream from the concurrent GEMM's MT."""
    p = 0.5
    c = _cil_coeff(machine, "comm", degree)
    cil = 1.0 + c * (min(degree, 3) - 1) * _mt_norm(gemm_shape, machine) ** p
    if degree > 3:
        cil *= 1.0 + 0.02 * (degree - 3)
    if not dma:
        cil += 0.10
    return cil


def hbm_move_time(nbytes: float, machine: MachineSpec) -> float:
    """Device-local HBM copy (read + write) — Gather/Scatter cost."""
    return machine.kernel_latency + 2.0 * nbytes / machine.hbm_bw


def loss_components(
    result,
    *,
    comm_cil: float | None = None,
    gemm_cil: float | None = None,
) -> dict:
    """Exactly-integrating loss decomposition of one simulated schedule.

    Splits a :class:`~repro.core.simulator.SimResult`'s end-to-end time
    into additive components that sum back to ``result.total`` in exact
    float arithmetic (modulo the usual summation rounding), so streaming
    accumulators can attribute *all* of a decision's time to a loss
    category and audits can assert ``sum(components) == total``:

      ``serial_gemm_s``          the isolated un-chunked GEMM
      ``gemm_decomposition_s``   DIL of the chunked GEMMs
                                 (busy/cil - serial: re-reads, launch
                                 latency, tile quantization)
      ``gemm_contention_s``      compute slowdown from concurrent
                                 streams (busy * (1 - 1/cil))
      ``exposed_comm_s``         comm the compute channel stalled on
      ``comm_tail_s``            comm outlasting the last compute step
                                 (total - compute-side finish; 0 when
                                 compute-bound)

    The CIL split needs the scalar factors the uniform lowering records
    (``ScheduleSteps.comm_cil``/``gemm_cil``); when they are absent
    (ragged lowerings apply CIL per step internally) the compute side
    stays whole:

      ``compute_busy_s`` + ``exposed_comm_s`` + ``comm_tail_s`` == total

    The pipeline recurrence guarantees ``total = max(compute_finish,
    comm_finish)`` with ``compute_finish = compute_busy + exposed``, so
    the tail term is what makes the identity hold in comm-bound regimes
    either way.
    """
    tail = result.total - result.compute_busy - result.exposed_comm
    if gemm_cil is not None:
        return {
            "serial_gemm_s": result.serial_gemm,
            "gemm_decomposition_s": (
                result.compute_busy / gemm_cil - result.serial_gemm
            ),
            "gemm_contention_s": (
                result.compute_busy * (1.0 - 1.0 / gemm_cil)
            ),
            "exposed_comm_s": result.exposed_comm,
            "comm_tail_s": tail,
        }
    return {
        "compute_busy_s": result.compute_busy,
        "exposed_comm_s": result.exposed_comm,
        "comm_tail_s": tail,
    }
