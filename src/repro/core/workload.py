"""Workload descriptors: the (M, N, K) GEMMs + collectives the paper studies.

Table I of the paper lists GEMMs from real deployments (Llama-2/3
tensor-sequence parallelism, DeepSeek/Mixtral expert parallelism).  Each
scenario is a data-dependent collective -> GEMM pair:

  * SP+TP:  all-gather of M-sharded activations, then GEMM with N-sharded
            weights (Figure 3 of the paper).
  * EP:     all-to-all token dispatch, then (grouped) expert GEMM.

Conventions (paper §IV-C1): the *global* GEMM is (M, N, K); the activation
input (M, K) starts row-sharded over the group; weights (K, N) are resident
(column-sharded over N, which does not interact with the overlap).  Static
quantities:

  OTB  (op-to-byte)   = flops / bytes_touched          (arithmetic intensity)
  MT   (memory traffic) = M*K + K*N + M*N  elements     (paper's definition)
"""

from __future__ import annotations

import dataclasses
import enum
import math


class CollectiveKind(enum.Enum):
    ALL_GATHER = "all_gather"
    ALL_TO_ALL = "all_to_all"


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """A global GEMM: out(M, N) = in(M, K) @ w(K, N)."""

    m: int
    n: int
    k: int
    dtype_bytes: int = 2  # bf16

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    @property
    def elems_mt(self) -> float:
        """Paper's memory-traffic metric MT, in elements."""
        return float(self.m * self.k + self.k * self.n + self.m * self.n)

    @property
    def bytes_mt(self) -> float:
        return self.elems_mt * self.dtype_bytes

    @property
    def otb(self) -> float:
        """Static op-to-byte ratio (paper §IV-C1)."""
        return self.flops / self.bytes_mt

    def shard(self, ways: int, axis: str) -> "GemmShape":
        """Decompose along 'm' (row), 'k' (inner) or 'n' (output col)."""
        if axis == "m":
            if self.m % ways:
                raise ValueError(f"M={self.m} not divisible by {ways}")
            return dataclasses.replace(self, m=self.m // ways)
        if axis == "k":
            if self.k % ways:
                raise ValueError(f"K={self.k} not divisible by {ways}")
            return dataclasses.replace(self, k=self.k // ways)
        if axis == "n":
            if self.n % ways:
                raise ValueError(f"N={self.n} not divisible by {ways}")
            return dataclasses.replace(self, n=self.n // ways)
        raise ValueError(f"axis must be 'm', 'n' or 'k', got {axis!r}")

    def device_gemm(self, group: int) -> "GemmShape":
        """The per-device GEMM in a TP group: weights are column (N) sharded
        across the group, so each device computes (M, N/g, K) after the
        all-gather of the (M, K) activation.  Table I lists global GEMMs."""
        if self.n % group == 0:
            return self.shard(group, "n")
        return self


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A data-dependent collective -> GEMM overlap scenario (Table I row)."""

    name: str
    parallelism: str  # "SP+TP" | "EP"
    model: str
    gemm: GemmShape
    collective: CollectiveKind = CollectiveKind.ALL_GATHER

    @property
    def comm_bytes_per_device(self) -> float:
        """Bytes each device must *receive* before the dependent GEMM.

        For AG of the (M, K) activation sharded M-ways over ``g`` devices the
        per-device ingress is (g-1)/g * M*K elements.  We report the full
        gathered buffer M*K (what lands in the operand); per-link math is in
        the simulator.
        """
        return float(self.gemm.m * self.gemm.k) * self.gemm.dtype_bytes


def _sc(name: str, par: str, model: str, m: int, n: int, k: int) -> Scenario:
    kind = (
        CollectiveKind.ALL_TO_ALL if par == "EP" else CollectiveKind.ALL_GATHER
    )
    return Scenario(name, par, model, GemmShape(m, n, k), kind)


# --------------------------------------------------------------------------
# Table I: GEMMs occurring in real world scenarios.
# --------------------------------------------------------------------------
TABLE_I: tuple[Scenario, ...] = (
    _sc("g1", "SP+TP", "llama-3-405b", 16384, 16384, 131072),
    _sc("g2", "SP+TP", "llama-3-405b", 131072, 16384, 16384),
    _sc("g3", "SP+TP", "llama-3-405b", 53248, 16384, 131072),
    _sc("g4", "SP+TP", "llama-3-405b", 131072, 53248, 16384),
    _sc("g5", "SP+TP", "llama-2-70b", 8192, 8192, 262144),
    _sc("g6", "SP+TP", "llama-2-70b", 262144, 8192, 8192),
    _sc("g7", "SP+TP", "llama-2-70b", 28672, 8192, 262144),
    _sc("g8", "SP+TP", "llama-2-70b", 262144, 28672, 8192),
    _sc("g9", "SP+TP", "llama-3-405b", 196608, 18432, 16384),
    _sc("g10", "SP+TP", "llama-3-405b", 196608, 106496, 16384),
    _sc("g11", "SP+TP", "llama-2-70b", 1048576, 10240, 8192),
    _sc("g12", "SP+TP", "llama-2-70b", 1048576, 57344, 8192),
    _sc("g13", "EP", "DeepSeek", 1607680, 57344, 8192),
    _sc("g14", "EP", "Mixtral", 147456, 28672, 4096),
    _sc("g15", "EP", "Mixtral", 327680, 28672, 4096),
    _sc("g16", "EP", "Mixtral", 229376, 28672, 4096),
)

SCENARIOS = {s.name: s for s in TABLE_I}


def synthetic_scenarios(count: int = 16, seed: int = 0) -> list[Scenario]:
    """Deterministic 'unseen' scenarios with diverse OTB / MT (paper §VI-D).

    Spans M/K both > and < 1, and several orders of magnitude of FLOPs, like
    the paper's sixteen synthetic evaluation points.
    """
    rng = _SplitMix(seed)
    out: list[Scenario] = []
    ms = [4096, 8192, 16384, 32768, 65536, 131072, 262144, 524288]
    ks = [2048, 4096, 8192, 16384, 32768, 65536, 131072]
    ns = [4096, 8192, 16384, 28672, 57344]
    while len(out) < count:
        m = ms[rng.next() % len(ms)]
        k = ks[rng.next() % len(ks)]
        n = ns[rng.next() % len(ns)]
        name = f"syn{len(out)}"
        out.append(_sc(name, "SP+TP", "synthetic", m, n, k))
    return out


# --------------------------------------------------------------------------
# Ragged step profiles: non-uniform per-step work (capacity-skewed EP
# dispatch, hetero-chunk FiCCO variants).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepProfile:
    """Per-step work fractions of a non-uniform FiCCO decomposition.

    ``fractions[s]`` is the share of the decomposed dimension (capacity
    rows for 1D schedules, K columns for 2D) carried by step ``s``; the
    shares sum to 1.  Zero entries are legal and model masked tail steps
    (a padded profile) or experts that received no tokens — the engines
    charge them exactly zero time and they can never stall the pipeline.

    The uniform ``g``-step schedule the paper studies is
    ``StepProfile.uniform(g)``; everything else widens the design space
    beyond the paper (ROADMAP "Non-uniform step lists").
    """

    fractions: tuple[float, ...]
    name: str = "custom"

    def __post_init__(self):
        if not self.fractions:
            raise ValueError("profile needs at least one step")
        if any(f < 0.0 for f in self.fractions):
            raise ValueError(f"negative step fraction in {self.fractions}")
        total = sum(self.fractions)
        if not math.isclose(total, 1.0, rel_tol=1e-9, abs_tol=1e-12):
            raise ValueError(f"fractions must sum to 1, got {total!r}")

    @property
    def steps(self) -> int:
        return len(self.fractions)

    @property
    def active_steps(self) -> int:
        return sum(1 for f in self.fractions if f > 0.0)

    @property
    def imbalance(self) -> float:
        """max/mean share over *active* steps: 1.0 == uniform."""
        act = [f for f in self.fractions if f > 0.0]
        return max(act) * len(act)

    @property
    def is_uniform(self) -> bool:
        return all(
            math.isclose(f, 1.0 / self.steps, rel_tol=1e-12)
            for f in self.fractions
        )

    def padded(self, steps: int) -> "StepProfile":
        """Zero-extend to ``steps`` entries (for batching mixed lengths)."""
        if steps < self.steps:
            raise ValueError(f"cannot pad {self.steps} steps down to {steps}")
        return dataclasses.replace(
            self, fractions=self.fractions + (0.0,) * (steps - self.steps)
        )

    def trimmed(self) -> "StepProfile":
        """Drop trailing zero steps (inverse of :meth:`padded`)."""
        last = max(
            (s for s, f in enumerate(self.fractions) if f > 0.0), default=0
        )
        return dataclasses.replace(self, fractions=self.fractions[: last + 1])

    def quantize(self, total: int) -> tuple[int, ...]:
        """Integer per-step sizes summing to ``total`` (largest remainder).

        Deterministic Hamilton rounding: floor every share, then hand the
        remainder out by descending fractional part (ties to the lower
        step index).  This is what the kernel layer uses to turn a load
        profile into concrete chunk row counts.
        """
        raw = [f * total for f in self.fractions]
        base = [int(math.floor(r)) for r in raw]
        rem = total - sum(base)
        order = sorted(
            range(self.steps), key=lambda s: (-(raw[s] - base[s]), s)
        )
        for s in order[:rem]:
            base[s] += 1
        return tuple(base)

    def digest(self) -> str:
        """Short stable identity string (autotune cache keys).

        Computed on the trimmed profile: zero padding is proven not to
        change any engine figure, so a padded profile must share its
        cache key with its trimmed twin rather than fragment the store.

        Memoized per instance — the class is frozen, so the identity
        never changes, and the hot decision paths (autotune cache,
        serving tier, signature stream) key by it on every call.
        """
        cached = self.__dict__.get("_digest")
        if cached is not None:
            return cached
        p = self.trimmed()
        if p.is_uniform:
            d = f"u{p.steps}"
        else:
            import hashlib

            h = hashlib.sha256()
            for f in p.fractions:
                h.update(repr(round(f, 12)).encode())
            d = f"{p.name}-{p.steps}-{h.hexdigest()[:10]}"
        object.__setattr__(self, "_digest", d)
        return d

    # -- constructors ---------------------------------------------------

    @classmethod
    def from_weights(cls, weights, name: str = "custom") -> "StepProfile":
        weights = [float(w) for w in weights]
        total = sum(weights)
        if total <= 0.0:
            raise ValueError("weights must have positive sum")
        return cls(tuple(w / total for w in weights), name=name)

    @classmethod
    def uniform(cls, steps: int) -> "StepProfile":
        return cls((1.0 / steps,) * steps, name="uniform")

    @classmethod
    def skewed(cls, steps: int, skew: float) -> "StepProfile":
        """Geometric capacity skew: step ``s`` carries weight ``skew**s``.

        ``skew=1`` is uniform; ``skew=2`` means each step carries twice
        the previous one's tokens (a hot-expert tail ramp); ``skew<1``
        front-loads.  The skew-factor sweep of the ragged scenario grid
        walks this knob.
        """
        if skew <= 0.0:
            raise ValueError(f"skew must be > 0, got {skew}")
        return cls.from_weights(
            [skew**s for s in range(steps)], name=f"skew{skew:g}"
        )

    @classmethod
    def zipf(cls, steps: int, alpha: float = 1.0) -> "StepProfile":
        """Zipf expert-load profile: weight ``1/(s+1)**alpha`` (hot head)."""
        return cls.from_weights(
            [1.0 / (s + 1) ** alpha for s in range(steps)],
            name=f"zipf{alpha:g}",
        )

    @classmethod
    def top_k_hot(
        cls, steps: int, hot: int = 1, hot_share: float = 0.5
    ) -> "StepProfile":
        """``hot`` steps split ``hot_share`` of the tokens; the rest split
        the remainder (top-k routing with a few saturated experts)."""
        if not 0 < hot < steps:
            raise ValueError(f"need 0 < hot < steps, got hot={hot}")
        if not 0.0 < hot_share < 1.0:
            raise ValueError(f"hot_share must be in (0, 1), got {hot_share}")
        cold = steps - hot
        return cls.from_weights(
            [hot_share / hot] * hot + [(1.0 - hot_share) / cold] * cold,
            name=f"top{hot}h{hot_share:g}",
        )


@dataclasses.dataclass(frozen=True)
class RaggedScenario:
    """A collective -> GEMM scenario with a non-uniform step profile.

    The profile describes how the decomposed dimension is split across
    FiCCO steps (e.g. per-chunk token counts of a capacity-skewed EP
    dispatch).  SERIAL and SHARD_P2P are profile-independent: they move
    the same aggregate bytes whatever the skew.
    """

    name: str
    parallelism: str
    model: str
    gemm: GemmShape
    profile: StepProfile
    collective: CollectiveKind = CollectiveKind.ALL_TO_ALL

    @classmethod
    def from_scenario(
        cls, scenario: Scenario, profile: StepProfile, suffix: str = ""
    ) -> "RaggedScenario":
        return cls(
            name=scenario.name + (suffix or f"/{profile.name}"),
            parallelism=scenario.parallelism,
            model=scenario.model,
            gemm=scenario.gemm,
            profile=profile,
            collective=scenario.collective,
        )


def ragged_scenario_grid(
    *,
    steps: int = 8,
    skews: tuple[float, ...] = (1.0, 2.0, 4.0),
    zipf_alphas: tuple[float, ...] = (1.0,),
    top_k: tuple[tuple[int, float], ...] = ((2, 0.6),),
    scenarios=None,
) -> list[RaggedScenario]:
    """Capacity-skewed EP-dispatch scenario families.

    Crosses the EP rows of Table I (or any caller-supplied scenarios)
    with a skew-factor sweep plus Zipf and top-k-hot expert load
    profiles — the non-uniform step lists real MoE serving produces.
    Feed the result straight to ``explore_grid`` (both backends accept
    ragged scenarios) or ``repro.core.batch.evaluate_ragged_grid``.
    """
    if scenarios is None:
        scenarios = [s for s in TABLE_I if s.parallelism == "EP"]
    profiles: list[StepProfile] = [
        StepProfile.skewed(steps, s) for s in skews
    ]
    profiles += [StepProfile.zipf(steps, a) for a in zipf_alphas]
    profiles += [StepProfile.top_k_hot(steps, h, share) for h, share in top_k]
    out: list[RaggedScenario] = []
    for sc in scenarios:
        for p in profiles:
            out.append(RaggedScenario.from_scenario(sc, p))
    return out


def tp_token_rows(global_batch: int, seq_len: int, dp: int = 16) -> int:
    """Per-replica token rows of one TP-SP block (M of its AG->GEMMs)."""
    b = global_batch // dp if global_batch >= dp else global_batch
    return b * seq_len


def tp_gemms(cfg, m: int, dtype_bytes: int = 2) -> dict:
    """The data-dependent TP-SP AG->GEMM pairs of one block (global dims).

    Single source of truth for what an architecture's overlap-relevant
    GEMMs are: MLP up-projection, fused QKV projection, and the MoE
    shared-expert projection when present.  Used by ``scenario_grid``,
    ``benchmarks/bench_arch_schedules`` and the hillclimb analytic
    prepass, so the three stay in agreement.
    """
    gemms: dict[str, GemmShape] = {}
    if cfg.d_ff:
        gemms["mlp_up"] = GemmShape(m, cfg.d_ff, cfg.d_model, dtype_bytes)
    h = cfg.num_heads * cfg.resolved_head_dim
    qkv = h + 2 * cfg.num_kv_heads * cfg.resolved_head_dim
    gemms["attn_qkv"] = GemmShape(m, qkv, cfg.d_model, dtype_bytes)
    if cfg.moe and cfg.moe.num_shared_experts:
        gemms["shared_expert"] = GemmShape(
            m,
            cfg.moe.d_ff_expert * cfg.moe.num_shared_experts,
            cfg.d_model,
            dtype_bytes,
        )
    return gemms


def scenario_grid(
    *,
    seqs: tuple[int, ...] = (1024, 2048, 4096, 8192, 16384, 32768, 65536),
    microbatches: tuple[int, ...] = (1, 3, 16),
    dtype_bytes: tuple[int, ...] = (2, 1),
) -> list[Scenario]:
    """Design-space scenario grid: every registry architecture's
    data-dependent AG->GEMMs crossed with token-row counts and dtypes
    (paper §VI-D scaled from 16 points to thousands).

    Each architecture contributes its TP-SP pairs (:func:`tp_gemms`); M
    is the per-replica token-row count ``seq x microbatch``, deduplicated
    across colliding (seq, microbatch) products so every grid point is
    distinct.  All M are multiples of 1024, so every group size up to 32
    decomposes them evenly (the batched engine masks indivisible
    combinations anyway).  Pair with :func:`machine_grid` for the
    machine axis; the full cross is what ``benchmarks/bench_sweep.py``
    pushes through ``explore_grid``.  The non-uniform counterpart is
    :func:`ragged_scenario_grid` (capacity-skewed EP families), which
    ``explore_grid`` also accepts directly.
    """
    from repro.configs import ARCHS, get_config  # local: keep layering thin

    ms = sorted({seq * mb for seq in seqs for mb in microbatches})
    out: list[Scenario] = []
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        kinds = sorted(tp_gemms(cfg, ms[0]))
        for kind in kinds:
            for m in ms:
                for b in dtype_bytes:
                    gemm = tp_gemms(cfg, m, dtype_bytes=b)[kind]
                    name = f"{arch}/{kind}/m{m}/b{b}"
                    out.append(Scenario(name, "SP+TP", arch, gemm))
    return out


def machine_grid(
    *,
    groups: tuple[int, ...] = (8, 16),
) -> list:
    """Machine axis of the design space: both reference machines crossed
    with overlap-group sizes and both studied topologies (full mesh vs
    torus ring), link counts adjusted to match."""
    from repro.core.machine import MACHINES, Topology

    out = []
    for base in MACHINES.values():
        for g in groups:
            for topo in (Topology.FULL_MESH, Topology.TORUS_RING):
                a2a = g - 1 if topo is Topology.FULL_MESH else 2
                out.append(
                    dataclasses.replace(
                        base,
                        name=f"{base.name}/g{g}/{topo.value}",
                        group=g,
                        topology=topo,
                        a2a_links=a2a,
                    )
                )
    return out


class _SplitMix:
    """Tiny deterministic PRNG so synthetic scenarios never drift."""

    def __init__(self, seed: int):
        self.state = (seed * 0x9E3779B97F4A7C15 + 1) & 0xFFFFFFFFFFFFFFFF

    def next(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return (z ^ (z >> 31)) & 0x7FFFFFFF


def geomean(xs) -> float:
    xs = list(xs)
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
