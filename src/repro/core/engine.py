"""Unified engine facade: one backend protocol from scalar ``simulate``
to jitted multi-host grids.

Three numerically-pinned engines evaluate the same ``(schedule, scenario,
machine)`` design-space grid:

  * :class:`ScalarEngine`  — the reference discrete simulator
    (``repro.core.simulator.simulate``) in Python loops; slow, obvious,
    the ground truth the other two are differential-tested against.
  * :class:`NumpyEngine`   — the vectorized batched engine
    (``repro.core.batch``); bit-identical to the scalar recurrence.
  * :class:`JaxEngine`     — the jit-compiled on-accelerator engine
    (``repro.autotune.jaxgrid``); ~1e-12 relative to NumPy, vmapped over
    machines, differentiable through TAU and machine parameters.

All three speak the same :class:`Engine` protocol — ``evaluate(batch) ->
GridResult`` for **uniform and ragged** scenario batches — and register
themselves in a process-wide registry, so everything downstream
(``explore_grid``, the autotuner shortlist, the heuristic calibrators,
``repro.sweep``) resolves a backend by name instead of branching on
``if backend == "jax"``:

    from repro.core.engine import get_engine
    grid = get_engine("jax").evaluate(scenarios, machines)

Capability flags (``supports_ragged``, ``jit``, ``differentiable``,
``trace_safe``) let callers pick an engine by property — e.g. the
autotuner drops from ``jax`` to ``numpy`` automatically when queried at
jax trace time, because :class:`JaxEngine` is not ``trace_safe``.

:class:`GridResult` — the one canonical dense result table — also lives
here; ``repro.core.batch`` and ``repro.autotune.jaxgrid`` re-export it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.machine import MachineSpec
from repro.core.schedule_types import STUDIED, Schedule
from repro.core.simulator import SimResult
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

# Canonical schedule order — matches the dict order of
# ``simulator.best_schedule`` so argmin tie-breaking is identical.
GRID_SCHEDULES: tuple[Schedule, ...] = (
    Schedule.SERIAL,
    Schedule.SHARD_P2P,
    *STUDIED,
)
SCHEDULE_INDEX = {s: i for i, s in enumerate(GRID_SCHEDULES)}

_FICCO_SCHEDULES = frozenset(STUDIED)


# ---------------------------------------------------------------------------
# The one canonical result table.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GridResult:
    """Dense result table over (schedule, scenario, machine).

    ``total``/``comm_busy``/``compute_busy``/``exposed`` have shape
    ``(L, S, M)`` with L = ``len(schedules)``; ``serial_comm`` /
    ``serial_gemm`` are ``(S, M)``.  Entries where the scalar simulator
    would raise (indivisible decompositions) are NaN with ``valid`` False.

    Every engine returns exactly this shape (scenario-major layout); the
    accelerator engines assemble it from their machine-major stacks via
    :meth:`from_machine_major`.
    """

    schedules: tuple[Schedule, ...]
    scenarios: "ScenarioBatch"  # noqa: F821 — repro.core.batch (no cycle)
    machines: tuple[MachineSpec, ...]
    total: np.ndarray
    comm_busy: np.ndarray
    compute_busy: np.ndarray
    exposed: np.ndarray
    steps: np.ndarray  # (L, M) int
    serial_comm: np.ndarray
    serial_gemm: np.ndarray
    valid: np.ndarray
    dma: bool

    @property
    def serial_total(self) -> np.ndarray:
        return self.serial_comm + self.serial_gemm

    @property
    def speedup(self) -> np.ndarray:
        """(L, S, M) speedup of each schedule vs the serial reference."""
        return self.serial_total[None, :, :] / self.total

    def best_idx(self) -> np.ndarray:
        """(S, M) index into ``schedules`` of the fastest valid schedule."""
        masked = np.where(self.valid, self.total, np.inf)
        return np.argmin(masked, axis=0)

    def best_total(self) -> np.ndarray:
        masked = np.where(self.valid, self.total, np.inf)
        return np.min(masked, axis=0)

    def schedule_idx(self, schedule: Schedule) -> int:
        return self.schedules.index(schedule)

    def sim_result(self, schedule: Schedule, i: int, j: int) -> SimResult:
        """Materialize one scalar :class:`SimResult` from the grid."""
        l = self.schedule_idx(schedule)
        if not self.valid[l, i, j]:
            raise ValueError(
                f"{schedule} invalid for scenario {i} on "
                f"{self.machines[j].name} (indivisible decomposition)"
            )
        return SimResult(
            schedule,
            float(self.total[l, i, j]),
            float(self.comm_busy[l, i, j]),
            float(self.compute_busy[l, i, j]),
            float(self.exposed[l, i, j]),
            int(self.steps[l, j]),
            float(self.serial_comm[i, j]),
            float(self.serial_gemm[i, j]),
        )

    @classmethod
    def from_machine_major(
        cls,
        raw,
        *,
        schedules,
        scenarios,
        machines,
        dma: bool,
    ) -> "GridResult":
        """Assemble from the accelerator engines' machine-major stacks.

        ``raw`` is the 8-tuple ``(total, comm_busy, compute_busy,
        exposed, steps, valid, serial_comm, serial_gemm)`` with a
        leading machine axis — ``total`` is ``(M, L, S)``, ``steps`` is
        ``(M, L)``, ``serial_*`` are ``(M, S)`` — exactly what
        ``jaxgrid.evaluate_grid_raw`` / ``evaluate_ragged_grid_raw``
        produce.  Transposed here, once, to the canonical scenario-major
        layout.
        """
        total, comm_busy, compute_busy, exposed, steps, valid, sc, sg = (
            np.asarray(a) for a in raw
        )
        return cls(
            schedules=tuple(schedules),
            scenarios=scenarios,
            machines=tuple(machines),
            total=np.transpose(total, (1, 2, 0)),
            comm_busy=np.transpose(comm_busy, (1, 2, 0)),
            compute_busy=np.transpose(compute_busy, (1, 2, 0)),
            exposed=np.transpose(exposed, (1, 2, 0)),
            steps=np.transpose(steps, (1, 0)),
            serial_comm=np.transpose(sc, (1, 0)),
            serial_gemm=np.transpose(sg, (1, 0)),
            valid=np.transpose(valid, (1, 2, 0)),
            dma=dma,
        )


# ---------------------------------------------------------------------------
# The engine protocol.
# ---------------------------------------------------------------------------


def as_scenario_sequence(scenarios):
    """Materialize generic iterables so dispatch can inspect them.

    Batches and lists/tuples pass through; generators and other
    iterables are drained to a list (otherwise :func:`is_ragged` would
    silently classify an iterator of RaggedScenario as uniform and the
    profiles would be dropped).
    """
    from repro.core.batch import ScenarioBatch

    if isinstance(scenarios, (ScenarioBatch, list, tuple)):
        return scenarios
    return list(scenarios)


def is_ragged(scenarios) -> bool:
    """True iff ``scenarios`` carries non-uniform step profiles.

    Pass generic iterables through :func:`as_scenario_sequence` first —
    this predicate does not consume iterators.
    """
    from repro.core.batch import RaggedBatch
    from repro.core.workload import RaggedScenario

    if isinstance(scenarios, RaggedBatch):
        return True
    if isinstance(scenarios, (list, tuple)) and len(scenarios) > 0:
        return isinstance(scenarios[0], RaggedScenario)
    return False


def _observe_evaluate(name: str, scenarios):
    """Span + counter for one engine evaluation (no-op when disabled)."""
    try:
        n = len(scenarios)
    except TypeError:  # raw generators: counted after coercion, skip here
        n = None
    _metrics.get_metrics().counter(f"engine/evaluate.{name}").inc()
    return _trace.span(
        "engine/evaluate", "engine", engine=name, n_scenarios=n
    )


@runtime_checkable
class Engine(Protocol):
    """One design-space evaluation backend.

    ``evaluate`` accepts every scenario form the engines accept today —
    ``ScenarioBatch`` / ``RaggedBatch`` / lists of ``Scenario`` /
    ``RaggedScenario`` / ``GemmShape`` — dispatching uniform vs ragged
    on the input type, and returns the canonical :class:`GridResult`.

    Capability flags:
      * ``supports_ragged`` — accepts non-uniform step profiles.
      * ``jit``            — compiled/on-accelerator evaluation.
      * ``differentiable`` — gradients flow through machine params/TAU.
      * ``trace_safe``     — callable while jax is tracing (a non-safe
        engine would stage its own computation into the caller's jaxpr).
    """

    name: str
    supports_ragged: bool
    jit: bool
    differentiable: bool
    trace_safe: bool

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ) -> GridResult: ...


class ScalarEngine:
    """Reference engine: ``simulate()`` in Python loops.

    O(S x M x L) Python-level work — the ground truth for differential
    tests and tiny queries, hopeless for design-space sweeps (the NumPy
    engine is >=50x faster; see ``benchmarks/bench_sweep.py``).
    Matches :class:`NumpyEngine` bit for bit: same formulas, same
    accumulation order (the batched pipeline scan replicates the scalar
    recurrence exactly).
    """

    name = "scalar"
    supports_ragged = True
    jit = False
    differentiable = False
    trace_safe = True

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ) -> GridResult:
        from repro.core import batch as _batch
        from repro.core.simulator import simulate

        schedules = (
            GRID_SCHEDULES if schedules is None else tuple(schedules)
        )
        scenarios = as_scenario_sequence(scenarios)
        ragged = is_ragged(scenarios)
        sb = (
            _batch._as_ragged_batch(scenarios)
            if ragged
            else _batch._as_batch(scenarios)
        )
        machines = tuple(machines)
        _span = _observe_evaluate(self.name, sb)
        _span.__enter__()
        L, S, M = len(schedules), len(sb), len(machines)
        total = np.full((L, S, M), np.nan)
        comm_busy = np.full((L, S, M), np.nan)
        compute_busy = np.full((L, S, M), np.nan)
        exposed = np.full((L, S, M), np.nan)
        steps = np.zeros((L, M), dtype=np.int64)
        serial_comm = np.zeros((S, M))
        serial_gemm = np.zeros((S, M))
        valid = np.zeros((L, S, M), dtype=bool)
        profiles = [sb.profile(i) for i in range(S)] if ragged else None
        for j, machine in enumerate(machines):
            # Step counts follow the engine convention (shared with the
            # batched engines): serial collapses to one step, everything
            # else pipelines over the group / padded profile length.
            for l, sched in enumerate(schedules):
                if sched is Schedule.SERIAL:
                    steps[l, j] = 1
                elif ragged and sched in _FICCO_SCHEDULES:
                    steps[l, j] = sb.max_steps
                else:
                    steps[l, j] = machine.group
            for i in range(S):
                gemm = sb.gemm(i)
                # Serial reference times are analytic metadata the
                # batched engines compute for every scenario whatever
                # the requested schedule subset — never raise.
                r0 = simulate(gemm, machine, Schedule.SERIAL, dma=dma)
                serial_comm[i, j] = r0.serial_comm
                serial_gemm[i, j] = r0.serial_gemm
                for l, sched in enumerate(schedules):
                    prof = (
                        profiles[i]
                        if ragged and sched in _FICCO_SCHEDULES
                        else None
                    )
                    try:
                        r = simulate(
                            gemm, machine, sched,
                            dma=dma, dma_into_place=dma_into_place,
                            profile=prof,
                        )
                    except ValueError:
                        continue  # indivisible decomposition: stays NaN
                    total[l, i, j] = r.total
                    comm_busy[l, i, j] = r.comm_busy
                    compute_busy[l, i, j] = r.compute_busy
                    exposed[l, i, j] = r.exposed_comm
                    valid[l, i, j] = True
        _span.__exit__(None, None, None)
        return GridResult(
            schedules=schedules,
            scenarios=sb,
            machines=machines,
            total=total,
            comm_busy=comm_busy,
            compute_busy=compute_busy,
            exposed=exposed,
            steps=steps,
            serial_comm=serial_comm,
            serial_gemm=serial_gemm,
            valid=valid,
            dma=dma,
        )


class NumpyEngine:
    """The vectorized batched engine (``repro.core.batch``)."""

    name = "numpy"
    supports_ragged = True
    jit = False
    differentiable = False
    trace_safe = True

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ) -> GridResult:
        from repro.core import batch as _batch

        scenarios = as_scenario_sequence(scenarios)
        fn = (
            _batch.evaluate_ragged_grid
            if is_ragged(scenarios)
            else _batch.evaluate_grid
        )
        with _observe_evaluate(self.name, scenarios):
            return fn(
                scenarios, machines, dma=dma, dma_into_place=dma_into_place,
                schedules=GRID_SCHEDULES if schedules is None else schedules,
            )


class JaxEngine:
    """The jit-compiled on-accelerator engine (``repro.autotune.jaxgrid``).

    Imported lazily: ``repro.core`` stays importable without jax, and
    resolving ``get_engine("jax")`` costs nothing until ``evaluate``.
    """

    name = "jax"
    supports_ragged = True
    jit = True
    differentiable = True
    trace_safe = False

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ) -> GridResult:
        from repro.autotune import jaxgrid

        scenarios = as_scenario_sequence(scenarios)
        fn = (
            jaxgrid.evaluate_ragged_grid
            if is_ragged(scenarios)
            else jaxgrid.evaluate_grid
        )
        with _observe_evaluate(self.name, scenarios):
            return fn(
                scenarios, machines, dma=dma, dma_into_place=dma_into_place,
                schedules=GRID_SCHEDULES if schedules is None else schedules,
            )


class MixedEngine:
    """Mixed-precision jitted engine (``repro.sweep.device``).

    The same jitted kernels as :class:`JaxEngine`, with the
    :class:`~repro.autotune.jaxgrid.MachineArrays` float leaves packed at
    ``dtype`` (float32 by default, bfloat16 on request) so the whole
    grid evaluates at reduced precision — float64 is confined to the
    pipeline scan's accumulator and the output container.  Built for
    sweep *throughput* (1e8-lane gate-training sweeps), not reference
    numerics: grids agree with the float64 engines only to the
    evaluation dtype's precision (see ``tests/test_device_sweep.py`` for
    the pinned tolerances).

    Honest capability flags: ``differentiable`` is False — gradients
    through bf16/f32 kernels are calibration-grade noise, so TAU /
    machine-parameter calibration must keep using the ``"jax"`` engine.
    """

    name = "mixed"
    supports_ragged = True
    jit = True
    differentiable = False
    trace_safe = False

    def __init__(self, dtype: str = "float32"):
        if dtype not in ("float64", "float32", "bfloat16"):
            raise ValueError(
                f"MixedEngine dtype must be float64|float32|bfloat16, "
                f"got {dtype!r}"
            )
        self.dtype = dtype

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ) -> GridResult:
        from repro.sweep import device as _device

        with _observe_evaluate(self.name, scenarios):
            return _device.evaluate_mixed_grid(
                scenarios, machines, dtype=self.dtype,
                dma=dma, dma_into_place=dma_into_place,
                schedules=GRID_SCHEDULES if schedules is None else schedules,
            )

    def dispatch(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ):
        """Asynchronously dispatch an evaluation; returns ``finalize()``.

        The returned zero-argument callable materializes the
        :class:`GridResult` (blocking on the device work).  This is the
        two-phase form ``repro.sweep.runner``'s double-buffered shard
        loop uses to keep shard k+1 in flight while shard k reduces.
        """
        from repro.sweep import device as _device

        return _device.dispatch_mixed_grid(
            scenarios, machines, dtype=self.dtype,
            dma=dma, dma_into_place=dma_into_place,
            schedules=GRID_SCHEDULES if schedules is None else schedules,
        )


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Engine]] = {}
_INSTANCES: dict[str, Engine] = {}


def register_engine(
    name: str,
    factory: Callable[[], Engine],
    *,
    overwrite: bool = False,
) -> None:
    """Register an engine factory under ``name``.

    Third parties (tests, experimental backends such as
    ``repro.learn.measured``) can register their own.  A name collision
    raises — registering over an existing engine would silently reroute
    every ``backend=`` caller — unless ``overwrite=True`` is passed
    explicitly; the error lists the registered names, mirroring
    :func:`get_engine`'s unknown-name diagnostic.
    """
    if not overwrite and name in _REGISTRY:
        raise ValueError(
            f"engine {name!r} already registered (pass overwrite=True to "
            f"replace it); registered engines: {', '.join(engine_names())}"
        )
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def engine_names() -> tuple[str, ...]:
    """Registered backend names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_engine(backend) -> Engine:
    """Resolve a backend name (or pass through an Engine instance).

    Unknown names raise a ``ValueError`` that lists every registered
    engine, so a typo'd ``backend=`` never falls through silently.
    """
    if not isinstance(backend, str):
        if isinstance(backend, Engine):
            return backend
        raise TypeError(
            f"backend must be an engine name or Engine, got {backend!r}"
        )
    factory = _REGISTRY.get(backend)
    if factory is None:
        raise ValueError(
            f"unknown engine backend {backend!r}; registered engines: "
            f"{', '.join(engine_names())}"
        )
    inst = _INSTANCES.get(backend)
    if inst is None:
        inst = _INSTANCES[backend] = factory()
    return inst


register_engine("scalar", ScalarEngine)
register_engine("numpy", NumpyEngine)
register_engine("jax", JaxEngine)
register_engine("mixed", MixedEngine)


# ---------------------------------------------------------------------------
# Backend-generic shortlist (what the autotuner ranks with).
# ---------------------------------------------------------------------------


def shortlist(
    gemm,
    machine: MachineSpec,
    *,
    top: int = 3,
    dma: bool = True,
    backend: str = "jax",
    profile=None,
    engine: Engine | None = None,
) -> list[tuple[Schedule, float]]:
    """Top-``top`` valid schedules for one GEMM, fastest first.

    ``backend`` names any registered engine (``engine=`` passes an
    instance directly).  Model times accompany each schedule so callers
    can decide whether measuring is worth it (close calls) or not.
    ``profile`` ranks the schedules under a ragged step profile instead
    of the uniform split (skew-aware tuning).
    """
    from repro.core.batch import RaggedBatch, ScenarioBatch

    eng = engine if engine is not None else get_engine(backend)
    if profile is not None:
        batch = RaggedBatch.from_batch_and_profiles(
            ScenarioBatch.from_gemms([gemm]), [profile]
        )
    else:
        batch = ScenarioBatch.from_gemms([gemm])
    grid = eng.evaluate(batch, (machine,), dma=dma)
    total = np.where(grid.valid[:, 0, 0], grid.total[:, 0, 0], np.inf)
    order = np.argsort(total, kind="stable")
    out = []
    for l in order[:top]:
        if not np.isfinite(total[l]):
            break
        out.append((grid.schedules[int(l)], float(total[l])))
    return out


__all__ = [
    "GRID_SCHEDULES",
    "SCHEDULE_INDEX",
    "GridResult",
    "Engine",
    "ScalarEngine",
    "NumpyEngine",
    "JaxEngine",
    "register_engine",
    "engine_names",
    "get_engine",
    "as_scenario_sequence",
    "is_ragged",
    "shortlist",
]
