"""Schedule taxonomy: the FiCCO design space (paper Fig. 11a).

Three axes:
  * communication shape:  1D (row/M-sharded chunks) or 2D (column/K-sharded
    chunks; requires accumulating GEMMs C += A @ B),
  * compute uniformity:   uniform (gather local+remote so every step runs the
    identical GEMM) or hetero (start on the local shard immediately),
  * compute granularity:  fused (one GEMM per step over all received chunks)
    or unfused (one GEMM per received chunk).

2^3 = 8 schedules; the paper studies the 4 whose inefficiency signatures are
not strictly dominated, plus the serial baseline and shard-granularity P2P
overlap.  We keep all 8 enumerable so the explorer can *demonstrate* the
pruning argument rather than assert it.
"""

from __future__ import annotations

import dataclasses
import enum


class CommShape(enum.Enum):
    ONE_D = "1d"  # chunks are row (M) slices
    TWO_D = "2d"  # chunks are column (K) slices -> accumulating GEMM


class Uniformity(enum.Enum):
    UNIFORM = "uniform"
    HETERO = "hetero"


class Granularity(enum.Enum):
    FUSED = "fused"
    UNFUSED = "unfused"


class Level(enum.IntEnum):
    LOW = 0
    MEDIUM = 1
    HIGH = 2


@dataclasses.dataclass(frozen=True)
class FiccoVariant:
    shape: CommShape
    uniformity: Uniformity
    granularity: Granularity

    @property
    def name(self) -> str:
        return (
            f"{self.uniformity.value}-{self.granularity.value}-"
            f"{self.shape.value}"
        )

    @property
    def needs_gather(self) -> bool:
        # Uniform schedules combine local + remote chunks into one buffer; a
        # fused-hetero step still gathers the (g-1) remote chunks received in
        # that step (they come from distinct peers, hence non-contiguous).
        return (
            self.uniformity is Uniformity.UNIFORM
            or self.granularity is Granularity.FUSED
        )

    @property
    def needs_scatter(self) -> bool:
        # 1D schedules compute on non-contiguous row groups -> outputs are
        # scattered back into the final output space.  2D accumulates the
        # full (M, N) output in place.
        return self.shape is CommShape.ONE_D

    @property
    def accumulating(self) -> bool:
        return self.shape is CommShape.TWO_D

    @property
    def concurrency_degree(self) -> int:
        """How many engines contend at steady state (drives CIL).

        comm is always concurrent (1) + compute (1) + gather (+1) +
        scatter (+1).  Matches the paper's qualitative CIL assignment:
        uniform-fused-1D highest, hetero-unfused-1D lowest.
        """
        return 2 + int(self.needs_gather) + int(self.needs_scatter)


class Schedule(enum.Enum):
    """The executable schedules studied in the paper (+ baselines)."""

    SERIAL = "serial"
    SHARD_P2P = "shard_p2p"  # AsyncTP-style ring at shard granularity
    UNIFORM_FUSED_1D = "uniform-fused-1d"
    HETERO_FUSED_1D = "hetero-fused-1d"
    HETERO_UNFUSED_1D = "hetero-unfused-1d"
    UNIFORM_FUSED_2D = "uniform-fused-2d"

    @property
    def is_ficco(self) -> bool:
        return self not in (Schedule.SERIAL, Schedule.SHARD_P2P)

    @property
    def variant(self) -> FiccoVariant:
        if not self.is_ficco:
            raise ValueError(f"{self} has no FiCCO variant")
        return _VARIANTS[self]


_VARIANTS = {
    Schedule.UNIFORM_FUSED_1D: FiccoVariant(
        CommShape.ONE_D, Uniformity.UNIFORM, Granularity.FUSED
    ),
    Schedule.HETERO_FUSED_1D: FiccoVariant(
        CommShape.ONE_D, Uniformity.HETERO, Granularity.FUSED
    ),
    Schedule.HETERO_UNFUSED_1D: FiccoVariant(
        CommShape.ONE_D, Uniformity.HETERO, Granularity.UNFUSED
    ),
    Schedule.UNIFORM_FUSED_2D: FiccoVariant(
        CommShape.TWO_D, Uniformity.UNIFORM, Granularity.FUSED
    ),
}

ALL_VARIANTS: tuple[FiccoVariant, ...] = tuple(
    FiccoVariant(s, u, g)
    for s in CommShape
    for u in Uniformity
    for g in Granularity
)

STUDIED: tuple[Schedule, ...] = (
    Schedule.UNIFORM_FUSED_1D,
    Schedule.HETERO_FUSED_1D,
    Schedule.HETERO_UNFUSED_1D,
    Schedule.UNIFORM_FUSED_2D,
)

# Paper Fig. 12a: qualitative inefficiency-loss signatures.
SIGNATURES: dict[Schedule, tuple[Level, Level]] = {
    # (DIL degree, CIL degree)
    Schedule.UNIFORM_FUSED_1D: (Level.LOW, Level.HIGH),
    Schedule.HETERO_FUSED_1D: (Level.MEDIUM, Level.MEDIUM),
    Schedule.HETERO_UNFUSED_1D: (Level.HIGH, Level.LOW),
    Schedule.UNIFORM_FUSED_2D: (Level.LOW, Level.HIGH),
}
