"""Discrete two-resource schedule simulator (paper Fig. 6 / Fig. 11b).

Every schedule is lowered to two serially-ordered work queues — a *comm
channel* (link DMAs) and a *compute channel* (GEMM + Gather/Scatter HBM
moves) — plus dependencies "compute step i needs comm step j".  The pipeline
recurrence then yields the end-to-end time:

    finish_comm[j]  = finish_comm[j-1] + comm[j]
    start_comp[i]   = max(finish_comp[i-1], finish_comm[dep(i)])
    total           = finish_comp[-1]

DIL is *not* injected: it emerges from the per-chunk roofline in
``inefficiency.gemm_exec`` (weight re-reads, launch latencies, tile
quantization).  CIL multiplies each stream's step times according to the
schedule's concurrency degree, matching the paper's calibrated geomeans.
"""

from __future__ import annotations

import dataclasses

from repro.core import inefficiency as ineff
from repro.core import schedule_types as _su
from repro.core.machine import MachineSpec
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape, StepProfile


@dataclasses.dataclass(frozen=True)
class SimResult:
    schedule: Schedule
    total: float
    comm_busy: float
    compute_busy: float
    exposed_comm: float
    steps: int
    # Isolated single-op reference times:
    serial_comm: float
    serial_gemm: float

    @property
    def serial_total(self) -> float:
        return self.serial_comm + self.serial_gemm

    @property
    def speedup(self) -> float:
        return self.serial_total / self.total

    @property
    def ideal_total(self) -> float:
        """Perfect overlap, zero DIL/CIL (paper's 'Ideal Execution')."""
        return max(self.serial_comm, self.serial_gemm)

    @property
    def ideal_speedup(self) -> float:
        return self.serial_total / self.ideal_total


@dataclasses.dataclass(frozen=True)
class ScheduleSteps:
    """A schedule lowered to its two work queues, before the pipeline runs.

    This is the intermediate representation ``simulate`` always built
    internally and then discarded; it is public so observability tooling
    (:mod:`repro.obs.timeline`) can render the per-step comm/compute
    lanes of any schedule without re-deriving the lowering.  ``run()``
    feeds the queues through the same pipeline recurrence ``simulate``
    uses — results are bit-identical to ``simulate``'s.

    ``comm_active``/``comp_active`` are the ragged path's step masks
    (None on uniform schedules).  ``comm_cil``/``gemm_cil`` record the
    contention factors applied to the *step* streams (None when the
    lowering applies them per-step internally, i.e. ragged), and
    ``local_first`` marks ``compute[0]`` as the un-communicated local
    shard GEMM (hetero FiCCO variants and shard-P2P).
    """

    schedule: Schedule
    comm: tuple[float, ...]
    compute: tuple[float, ...]
    deps: tuple[int | None, ...]
    steps: int
    serial_comm: float
    serial_gemm: float
    comm_active: tuple[bool, ...] | None = None
    comp_active: tuple[bool, ...] | None = None
    comm_cil: float | None = None
    gemm_cil: float | None = None
    local_first: bool = False

    def run(self) -> SimResult:
        if self.comm_active is not None:
            total, exposed, comm_busy, compute_busy = _pipeline_masked(
                list(self.comm),
                list(self.compute),
                list(self.deps),
                list(self.comm_active),
                list(self.comp_active),
            )
        else:
            total, exposed = _pipeline(
                list(self.comm), list(self.compute), list(self.deps)
            )
            comm_busy = sum(self.comm)
            compute_busy = sum(self.compute)
        return SimResult(
            self.schedule, total, comm_busy, compute_busy, exposed,
            self.steps, self.serial_comm, self.serial_gemm,
        )


def _pipeline(
    comm: list[float], compute: list[float], deps: list[int | None]
) -> tuple[float, float]:
    """Run the two-channel pipeline; returns (total, exposed_comm)."""
    finish_comm: list[float] = []
    t = 0.0
    for c in comm:
        t += c
        finish_comm.append(t)
    t_comp = 0.0
    exposed = 0.0
    for i, work in enumerate(compute):
        dep = deps[i]
        ready = finish_comm[dep] if dep is not None else 0.0
        if ready > t_comp:
            exposed += ready - t_comp
            t_comp = ready
        t_comp += work
    return max(t_comp, finish_comm[-1] if finish_comm else 0.0), exposed


def _pipeline_masked(
    comm: list[float],
    compute: list[float],
    deps: list[int | None],
    comm_active: list[bool],
    comp_active: list[bool],
) -> tuple[float, float, float, float]:
    """Masked ragged pipeline: the scalar twin of the batched engines'
    masked scans (``batch.pipeline_vec`` with masks, ``jaxgrid.
    pipeline_jax``).

    Inactive steps add exactly 0.0 time on their channel and can never
    stall the compute channel, so a zero-padded profile reproduces its
    trimmed recurrence bit-for-bit.  Returns ``(total, exposed,
    comm_busy, compute_busy)``.
    """
    finish: list[float] = []
    t = 0.0
    for c, a in zip(comm, comm_active):
        t = t + (c if a else 0.0)
        finish.append(t)
    t_comp = 0.0
    exposed = 0.0
    comp_sum = 0.0
    for i, work in enumerate(compute):
        a = comp_active[i]
        w = work if a else 0.0
        dep = deps[i]
        if dep is not None and a:
            ready = finish[dep]
            if ready > t_comp:
                exposed += ready - t_comp
                t_comp = ready
        t_comp += w
        comp_sum += w
    comm_sum = finish[-1] if finish else 0.0
    return max(t_comp, comm_sum), exposed, comm_sum, comp_sum


def simulate(
    gemm: GemmShape,
    machine: MachineSpec,
    schedule: Schedule,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    profile: StepProfile | None = None,
) -> SimResult:
    """Simulate one data-dependent AG->GEMM (or A2A->GEMM) scenario.

    ``dma_into_place`` models the beyond-paper fused Pallas kernel
    (repro.kernels.ficco_ag_matmul): chunks are DMA'd directly into the
    step buffer and outputs written in place, eliminating the Gather /
    Scatter streams — lower concurrency degree AND no gather/scatter
    residual time.  On the paper's GPU realization those streams exist
    because receive buffers are separate (hence uniform schedules' HIGH
    CIL signature); TPU strided remote DMA removes them.

    ``profile`` selects the **ragged** path: per-step chunk sizes follow
    the :class:`~repro.core.workload.StepProfile` (capacity-skewed EP
    dispatch, hetero-chunk FiCCO variants) instead of the paper's
    uniform 1/g split.  SERIAL and SHARD_P2P are profile-independent —
    they move the same aggregate bytes whatever the skew — so a profile
    passed with those schedules is accepted and ignored.
    """
    return schedule_steps(
        gemm, machine, schedule,
        dma=dma, dma_into_place=dma_into_place, profile=profile,
    ).run()


def schedule_steps(
    gemm: GemmShape,
    machine: MachineSpec,
    schedule: Schedule,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    profile: StepProfile | None = None,
) -> ScheduleSteps:
    """Lower one scenario to its per-step comm/compute work queues.

    This is :func:`simulate` stopped one stage early:
    ``schedule_steps(...).run()`` *is* ``simulate(...)``, bit for bit.
    The exposed queues are what the schedule-timeline exporter renders
    as Perfetto lanes.
    """
    g = machine.group
    b = gemm.dtype_bytes
    # Per-device GEMM: TP column-shards the weight over the group, so the
    # data-dependent GEMM each device runs is (M, N/g, K) (Table I lists
    # global GEMMs).  The all-gathered activation is the full (M, K).
    dev = gemm.device_gemm(g)
    mk_bytes = float(gemm.m * gemm.k) * b
    serial_comm = ineff.ag_serial_time(mk_bytes, machine)
    serial_gemm = ineff.gemm_exec(dev, machine).time

    if schedule is Schedule.SERIAL:
        # One AG, one GEMM, GEMM depends on the AG: the pipeline
        # recurrence reproduces total = serial_comm + serial_gemm with
        # the whole AG exposed.
        return ScheduleSteps(
            schedule, (serial_comm,), (serial_gemm,), (0,), 1,
            serial_comm, serial_gemm, comm_cil=1.0, gemm_cil=1.0,
        )

    if schedule is Schedule.SHARD_P2P:
        return _steps_shard_p2p(
            gemm, dev, machine, serial_comm, serial_gemm, dma
        )

    if profile is not None:
        return _steps_ficco_ragged(
            gemm, machine, schedule, profile, serial_comm, serial_gemm,
            dma, dma_into_place,
        )
    return _steps_ficco(
        gemm, dev, machine, schedule, serial_comm, serial_gemm, dma,
        dma_into_place,
    )


def _steps_shard_p2p(
    gemm: GemmShape,
    dev: GemmShape,
    machine: MachineSpec,
    serial_comm: float,
    serial_gemm: float,
    dma: bool,
) -> ScheduleSteps:
    g = machine.group
    shard = dev.shard(g, "m")
    shard_bytes = float(shard.m * shard.k) * gemm.dtype_bytes
    deg = 2  # comm + compute only
    c_cil = ineff.comm_cil(shard, machine, degree=deg, dma=dma)
    g_cil = ineff.gemm_cil(shard, machine, degree=deg, dma=dma)
    t_p2p = ineff.p2p_step_time(shard_bytes, machine) * c_cil
    t_gemm = ineff.gemm_exec(shard, machine).time * g_cil
    # compute_0 = local shard (no dep); compute_i needs P2P step i-1.
    comm = (t_p2p,) * (g - 1)
    compute = (t_gemm,) * g
    deps: tuple[int | None, ...] = (None, *range(g - 1))
    return ScheduleSteps(
        Schedule.SHARD_P2P, comm, compute, deps, g,
        serial_comm, serial_gemm,
        comm_cil=c_cil, gemm_cil=g_cil, local_first=True,
    )


def _steps_ficco(
    gemm: GemmShape,
    dev: GemmShape,
    machine: MachineSpec,
    schedule: Schedule,
    serial_comm: float,
    serial_gemm: float,
    dma: bool,
    dma_into_place: bool = False,
) -> ScheduleSteps:
    g = machine.group
    b = gemm.dtype_bytes
    var = schedule.variant
    m_s = dev.m // g  # shard rows

    if schedule is Schedule.UNIFORM_FUSED_2D:
        # chunks are (m_s, K/g); step GEMM is accumulating (M, N, K/g).
        chunk_bytes = float(m_s * (dev.k // g)) * b
        step_gemm = dev.shard(g, "k")
        gather_bytes = float(dev.m * (dev.k // g)) * b
        scatter_bytes = 0.0
        degree = 4  # comm + gather + compute + C accumulate traffic
        accumulate = True
        n_comm, n_comp = g, g
        local_first = None
        per_step_gemms = 1
    elif schedule is Schedule.UNIFORM_FUSED_1D:
        chunk_bytes = float((m_s // g) * dev.k) * b
        step_gemm = dev.shard(g, "m")
        gather_bytes = float(m_s * dev.k) * b
        scatter_bytes = float(m_s * dev.n) * b
        degree = 4  # comm + gather + compute + scatter
        accumulate = False
        n_comm, n_comp = g, g
        local_first = None
        per_step_gemms = 1
    elif schedule is Schedule.HETERO_FUSED_1D:
        chunk_bytes = float((m_s // g) * dev.k) * b
        rows = (g - 1) * (m_s // g)
        step_gemm = GemmShape(rows, dev.n, dev.k, b)
        gather_bytes = float(rows * dev.k) * b
        scatter_bytes = float(rows * dev.n) * b
        degree = 3  # gather is remote-only and smaller
        accumulate = False
        n_comm, n_comp = g, g
        local_first = dev.shard(g, "m")
        per_step_gemms = 1
    elif schedule is Schedule.HETERO_UNFUSED_1D:
        chunk_bytes = float((m_s // g) * dev.k) * b
        step_gemm = GemmShape(m_s // g, dev.n, dev.k, b)
        gather_bytes = 0.0  # computes directly on each received chunk
        scatter_bytes = float((g - 1) * (m_s // g) * dev.n) * b
        degree = 2  # comm + compute (scatter folded into epilogue)
        accumulate = False
        n_comm, n_comp = g, g
        local_first = dev.shard(g, "m")
        per_step_gemms = g - 1
    else:  # pragma: no cover
        raise ValueError(schedule)

    if dma_into_place:
        # fused kernel: no separate gather/scatter streams
        gather_bytes = 0.0
        scatter_bytes = 0.0
        degree = 2
    c_cil = ineff.comm_cil(dev.shard(g, "m"), machine, degree=degree, dma=dma)
    g_cil = ineff.gemm_cil(step_gemm, machine, degree=degree, dma=dma)

    t_comm = ineff.a2a_chunk_step_time(chunk_bytes, machine) * c_cil
    t_gemm_step = (
        per_step_gemms
        * ineff.gemm_exec(step_gemm, machine, accumulate=accumulate).time
        * g_cil
    )
    # Gather/Scatter are DMA streams concurrent with compute+comm (paper:
    # "uniform-fused-1D can execute communication, gather, compute, and
    # scatter at the same time") — their pressure is what raises the
    # schedule's concurrency degree / CIL; only residual non-hidden time
    # (when they exceed the GEMM) serializes.
    t_gather = ineff.hbm_move_time(gather_bytes, machine) if gather_bytes else 0.0
    t_scatter = (
        ineff.hbm_move_time(scatter_bytes, machine) if scatter_bytes else 0.0
    )
    t_step = max(t_gemm_step, t_gather + t_scatter)

    comm = (t_comm,) * n_comm
    if local_first is not None:
        t_local = (
            ineff.gemm_exec(local_first, machine).time
            * ineff.gemm_cil(local_first, machine, degree=degree, dma=dma)
        )
        compute: tuple[float, ...] = (t_local, *((t_step,) * n_comp))
        deps: tuple[int | None, ...] = (None, *range(n_comm))
    else:
        compute = (t_step,) * n_comp
        deps = tuple(range(n_comm))
    return ScheduleSteps(
        schedule, comm, compute, deps, n_comm, serial_comm, serial_gemm,
        comm_cil=c_cil, gemm_cil=g_cil,
        local_first=local_first is not None,
    )


def _steps_ficco_ragged(
    gemm: GemmShape,
    machine: MachineSpec,
    schedule: Schedule,
    profile: StepProfile,
    serial_comm: float,
    serial_gemm: float,
    dma: bool,
    dma_into_place: bool,
) -> ScheduleSteps:
    """Ragged FiCCO: per-step times from the shared step-time model
    (``batch.ragged_step_times`` with S == 1), scanned by the scalar
    masked pipeline.  Raises ValueError exactly where the batched
    engine's validity mask is False (indivisible M)."""
    import numpy as np  # local: the scalar core otherwise avoids numpy

    from repro.core import batch as _batch  # local: avoids a cycle

    m = np.array([gemm.m], dtype=np.int64)
    n = np.array([gemm.n], dtype=np.int64)
    k = np.array([gemm.k], dtype=np.int64)
    b = np.array([gemm.dtype_bytes], dtype=np.int64)
    frac = np.array([profile.fractions], dtype=np.float64)
    comm_v, compute_v, deps, c_act, w_act, ok = _batch.ragged_step_times(
        m, n, k, b, frac, machine, schedule,
        dma=dma, dma_into_place=dma_into_place,
    )
    if not bool(ok[0]):
        raise ValueError(
            f"M={gemm.m} not divisible by group {machine.group} for "
            f"ragged {schedule}"
        )
    comm = tuple(float(c[0]) for c in comm_v)
    compute = tuple(float(w[0]) for w in compute_v)
    comm_active = tuple(bool(a[0]) for a in c_act)
    comp_active = tuple(bool(a[0]) for a in w_act)
    return ScheduleSteps(
        schedule, comm, compute, tuple(deps), profile.steps,
        serial_comm, serial_gemm,
        comm_active=comm_active, comp_active=comp_active,
        local_first=(
            schedule.variant.uniformity is _su.Uniformity.HETERO
        ),
    )


def best_schedule(
    gemm: GemmShape, machine: MachineSpec, *, dma: bool = True
) -> tuple[Schedule, dict[Schedule, SimResult]]:
    """Simulator-optimal schedule among the studied four + baselines."""
    from repro.core.schedule_types import STUDIED

    results = {
        s: simulate(gemm, machine, s, dma=dma)
        for s in (Schedule.SERIAL, Schedule.SHARD_P2P, *STUDIED)
    }
    best = min(results, key=lambda s: results[s].total)
    return best, results
