"""Design-space explorer: enumerate, simulate and rank every schedule.

This reproduces the paper's §V-B pruning argument programmatically: of the
eight combinatorial FiCCO schedules, the four not studied have inefficiency
signatures that are (near-)strictly dominated.  ``explore`` ranks all
executable schedules for a scenario; ``prune_report`` shows why the four
extra design points lose.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import inefficiency as ineff
from repro.core.batch import GridResult, RaggedBatch
from repro.core.engine import Engine, get_engine
from repro.core.heuristics import (
    HeuristicDecision,
    select_schedule,
    select_schedule_batch,
)
from repro.core.machine import MI300X, MachineSpec
from repro.core.schedule_types import (
    ALL_VARIANTS,
    STUDIED,
    CommShape,
    FiccoVariant,
    Granularity,
    Schedule,
    Uniformity,
)
from repro.core.simulator import SimResult, simulate
from repro.core.workload import GemmShape, Scenario


@dataclasses.dataclass(frozen=True)
class Exploration:
    scenario: Scenario
    results: dict[Schedule, SimResult]
    best: Schedule
    heuristic: HeuristicDecision

    @property
    def heuristic_correct(self) -> bool:
        return self.heuristic.schedule is self.best

    @property
    def heuristic_loss(self) -> float:
        """Fraction of the optimal speedup lost by the heuristic's pick."""
        opt = self.results[self.best].speedup
        got = self.results[self.heuristic.schedule].speedup
        if opt <= 1.0:
            return 0.0
        return max(0.0, (opt - got) / (opt - 1.0))


def explore(
    scenario: Scenario, machine: MachineSpec, *, dma: bool = True
) -> Exploration:
    results = {
        s: simulate(scenario.gemm, machine, s, dma=dma)
        for s in (Schedule.SERIAL, Schedule.SHARD_P2P, *STUDIED)
    }
    best = min(results, key=lambda s: results[s].total)
    return Exploration(
        scenario, results, best, select_schedule(scenario.gemm, machine)
    )


@dataclasses.dataclass(frozen=True)
class GridExploration:
    """Batched exploration: simulator grid + vectorized heuristic picks.

    All arrays are indexed ``[scenario, machine]``; schedule identities are
    indices into ``grid.schedules`` (== ``GRID_SCHEDULES``).
    """

    grid: GridResult
    heuristic_idx: np.ndarray  # (S, M) indices into grid.schedules

    @classmethod
    def from_grid(
        cls, grid: GridResult, *, tau: float | None = None, gate=None
    ) -> "GridExploration":
        """Attach vectorized heuristic picks to an already-evaluated grid.

        Works on any engine's :class:`GridResult` (the heuristic is
        engine-independent); ragged grids feed their per-scenario
        imbalance (and active step counts) into the skew-aware serial
        gate.  ``gate`` (a :class:`repro.learn.gate.LearnedGate`) swaps
        the scalar gate for the sweep-learned threshold family.
        """
        sb = grid.scenarios
        if isinstance(sb, RaggedBatch):
            imbalance = sb.imbalance
            active_steps = sb.active_steps
        else:
            imbalance = None
            active_steps = None
        heuristic = np.stack(
            [
                select_schedule_batch(
                    sb.m, sb.n, sb.k, sb.dtype_bytes, machine, tau=tau,
                    imbalance=imbalance, active_steps=active_steps,
                    gate=gate,
                )
                for machine in grid.machines
            ],
            axis=1,
        )
        return cls(grid, heuristic)

    @property
    def best_idx(self) -> np.ndarray:
        return self.grid.best_idx()

    @property
    def exact(self) -> np.ndarray:
        """(S, M) bool: heuristic picked the simulator-optimal schedule."""
        return self.heuristic_idx == self.best_idx

    def heuristic_total(self) -> np.ndarray:
        """(S, M) simulated time of the heuristic's pick."""
        s_idx = np.arange(len(self.grid.scenarios))[:, None]
        m_idx = np.arange(len(self.grid.machines))[None, :]
        return self.grid.total[self.heuristic_idx, s_idx, m_idx]

    def within(self, frac: float = 0.05) -> np.ndarray:
        """(S, M) bool: heuristic pick within ``frac`` of optimal time."""
        return self.heuristic_total() <= (1.0 + frac) * self.grid.best_total()

    def heuristic_loss(self) -> np.ndarray:
        """(S, M) fraction of the optimal speedup lost by the heuristic."""
        serial = self.grid.serial_total
        opt = serial / self.grid.best_total()
        got = serial / self.heuristic_total()
        with np.errstate(invalid="ignore", divide="ignore"):
            loss = (opt - got) / (opt - 1.0)
        return np.where(opt <= 1.0, 0.0, np.maximum(loss, 0.0))

    def accuracy(self, frac: float | None = None) -> float:
        """Scalar grid-wide accuracy (exact, or within ``frac`` if given)."""
        hits = self.exact if frac is None else self.within(frac)
        return float(np.mean(hits))

    def mean_misprediction_loss(self) -> float:
        """Mean speedup loss over mispredicted points (paper: ~14%)."""
        miss = ~self.exact
        if not miss.any():
            return 0.0
        # nanmean: a pick that is invalid on some machine (indivisible
        # decomposition) has no simulated time to compare against.
        return float(np.nanmean(self.heuristic_loss()[miss]))

    def summary(self) -> str:
        return (
            f"{self.exact.size} (scenario x machine) points: "
            f"exact {100 * self.accuracy():.1f}%, "
            f"within5% {100 * self.accuracy(0.05):.1f}%, "
            f"mean misprediction loss "
            f"{100 * self.mean_misprediction_loss():.1f}%"
        )


def explore_grid(
    scenarios,
    machines=(MI300X,),
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    tau: float | None = None,
    backend: str = "numpy",
    engine: Engine | None = None,
    gate=None,
) -> GridExploration:
    """Batched :func:`explore` over S scenarios x M machines at once.

    Three lines to sweep a design space::

        from repro.core import TABLE_I, MI300X, TPU_V5E, explore_grid
        ex = explore_grid(TABLE_I, machines=[MI300X, TPU_V5E])
        print(ex.summary())

    ``scenarios`` accepts Scenario lists, GemmShape lists or a prebuilt
    :class:`~repro.core.batch.ScenarioBatch` (e.g. from
    ``workload.scenario_grid``).  ``backend`` names any engine in the
    :mod:`repro.core.engine` registry — ``"numpy"`` (default),
    ``"jax"`` (jit-compiled, identical numbers within 1e-5, faster per
    sweep once compiled, differentiable for calibration) or
    ``"scalar"`` (the reference simulator loop); an unknown name raises
    a ``ValueError`` listing the registered engines.  ``engine=``
    passes an :class:`~repro.core.engine.Engine` instance directly.

    **Ragged scenarios** (:class:`~repro.core.workload.RaggedScenario`
    lists / a :class:`~repro.core.batch.RaggedBatch`, e.g. from
    ``workload.ragged_scenario_grid``) route through the masked ragged
    engines on any backend; the heuristic picks then carry the
    skew-aware serial gate (``imbalance``).

    ``gate`` (a :class:`repro.learn.gate.LearnedGate`) evaluates the
    heuristic with the sweep-learned threshold family instead of the
    scalar serial gate.
    """
    eng = engine if engine is not None else get_engine(backend)
    grid = eng.evaluate(
        scenarios, machines, dma=dma, dma_into_place=dma_into_place
    )
    return GridExploration.from_grid(grid, tau=tau, gate=gate)


def _variant_proxy_time(
    variant: FiccoVariant, gemm: GemmShape, machine: MachineSpec
) -> float:
    """Signature-level time proxy for *any* of the 8 variants.

    Used only to rank unstudied variants against studied ones: per-step GEMM
    size fixes DIL (via the chunk roofline), concurrency degree fixes CIL.
    """
    g = machine.group
    dev = gemm.device_gemm(g)
    if variant.shape is CommShape.TWO_D:
        base = dev.shard(g, "k")
        if variant.uniformity is Uniformity.HETERO:
            # hetero-2D: local K-slice first, then row-sharded remote K-slices
            # -> chunk GEMM additionally row-sharded: strictly smaller GEMM.
            base = base.shard(g, "m")
        if variant.granularity is Granularity.UNFUSED:
            base = base.shard(g, "m") if base.m >= g else base
        accumulate = True
    else:
        base = dev.shard(g, "m")
        if variant.granularity is Granularity.UNFUSED:
            base = base.shard(g, "m")
        accumulate = False
    # Chunk count follows from covering the device GEMM's total work.
    chunks = max(1, round(dev.flops / base.flops))
    per = ineff.gemm_exec(base, machine, accumulate=accumulate).time
    cil = ineff.gemm_cil(base, machine, degree=variant.concurrency_degree)
    chunk_bytes = float(gemm.m * gemm.k) * gemm.dtype_bytes / (g * g)
    t_comm = g * ineff.a2a_chunk_step_time(chunk_bytes, machine)
    compute = chunks * per * cil
    return max(compute, t_comm) + t_comm / g  # one exposed comm step


def prune_report(
    scenario: Scenario, machine: MachineSpec
) -> list[tuple[str, float, bool]]:
    """(variant-name, proxy time, studied?) for all 8 variants, sorted."""
    studied_names = {s.variant.name for s in STUDIED}
    rows = []
    for v in ALL_VARIANTS:
        t = _variant_proxy_time(v, scenario.gemm, machine)
        rows.append((v.name, t, v.name in studied_names))
    rows.sort(key=lambda r: r[1])
    return rows
