"""Design-space explorer: enumerate, simulate and rank every schedule.

This reproduces the paper's §V-B pruning argument programmatically: of the
eight combinatorial FiCCO schedules, the four not studied have inefficiency
signatures that are (near-)strictly dominated.  ``explore`` ranks all
executable schedules for a scenario; ``prune_report`` shows why the four
extra design points lose.
"""

from __future__ import annotations

import dataclasses

from repro.core import inefficiency as ineff
from repro.core.heuristics import HeuristicDecision, select_schedule
from repro.core.machine import MachineSpec
from repro.core.schedule_types import (
    ALL_VARIANTS,
    STUDIED,
    CommShape,
    FiccoVariant,
    Granularity,
    Schedule,
    Uniformity,
)
from repro.core.simulator import SimResult, simulate
from repro.core.workload import GemmShape, Scenario


@dataclasses.dataclass(frozen=True)
class Exploration:
    scenario: Scenario
    results: dict[Schedule, SimResult]
    best: Schedule
    heuristic: HeuristicDecision

    @property
    def heuristic_correct(self) -> bool:
        return self.heuristic.schedule is self.best

    @property
    def heuristic_loss(self) -> float:
        """Fraction of the optimal speedup lost by the heuristic's pick."""
        opt = self.results[self.best].speedup
        got = self.results[self.heuristic.schedule].speedup
        if opt <= 1.0:
            return 0.0
        return max(0.0, (opt - got) / (opt - 1.0))


def explore(
    scenario: Scenario, machine: MachineSpec, *, dma: bool = True
) -> Exploration:
    results = {
        s: simulate(scenario.gemm, machine, s, dma=dma)
        for s in (Schedule.SERIAL, Schedule.SHARD_P2P, *STUDIED)
    }
    best = min(results, key=lambda s: results[s].total)
    return Exploration(
        scenario, results, best, select_schedule(scenario.gemm, machine)
    )


def _variant_proxy_time(
    variant: FiccoVariant, gemm: GemmShape, machine: MachineSpec
) -> float:
    """Signature-level time proxy for *any* of the 8 variants.

    Used only to rank unstudied variants against studied ones: per-step GEMM
    size fixes DIL (via the chunk roofline), concurrency degree fixes CIL.
    """
    g = machine.group
    dev = gemm.device_gemm(g)
    if variant.shape is CommShape.TWO_D:
        base = dev.shard(g, "k")
        if variant.uniformity is Uniformity.HETERO:
            # hetero-2D: local K-slice first, then row-sharded remote K-slices
            # -> chunk GEMM additionally row-sharded: strictly smaller GEMM.
            base = base.shard(g, "m")
        if variant.granularity is Granularity.UNFUSED:
            base = base.shard(g, "m") if base.m >= g else base
        accumulate = True
    else:
        base = dev.shard(g, "m")
        if variant.granularity is Granularity.UNFUSED:
            base = base.shard(g, "m")
        accumulate = False
    # Chunk count follows from covering the device GEMM's total work.
    chunks = max(1, round(dev.flops / base.flops))
    per = ineff.gemm_exec(base, machine, accumulate=accumulate).time
    cil = ineff.gemm_cil(base, machine, degree=variant.concurrency_degree)
    chunk_bytes = float(gemm.m * gemm.k) * gemm.dtype_bytes / (g * g)
    t_comm = g * ineff.a2a_chunk_step_time(chunk_bytes, machine)
    compute = chunks * per * cil
    return max(compute, t_comm) + t_comm / g  # one exposed comm step


def prune_report(
    scenario: Scenario, machine: MachineSpec
) -> list[tuple[str, float, bool]]:
    """(variant-name, proxy time, studied?) for all 8 variants, sorted."""
    studied_names = {s.variant.name for s in STUDIED}
    rows = []
    for v in ALL_VARIANTS:
        t = _variant_proxy_time(v, scenario.gemm, machine)
        rows.append((v.name, t, v.name in studied_names))
    rows.sort(key=lambda r: r[1])
    return rows
