"""Vectorized batched design-space engine: thousands of FiCCO scenarios/sec.

The scalar simulator (``repro.core.simulator``) walks one ``(scenario,
machine, schedule)`` triple at a time in Python — fine for the 16 Table-I
rows, hopeless for design-space sweeps over every registry architecture x
dtype x group size x topology.  This module evaluates the *whole grid* in
NumPy array math:

  * the roofline GEMM model (:func:`gemm_exec_vec`): tiles, split-K,
    occupancy, reduction ramp — all elementwise over ``(S,)`` shape arrays;
  * the communication model (:func:`ag_serial_time_vec`,
    :func:`a2a_chunk_step_time_vec`, :func:`p2p_step_time_vec`);
  * the CIL interference formulas (:func:`gemm_cil_vec`,
    :func:`comm_cil_vec`), reusing the machine-level calibrated
    coefficients from ``repro.core.inefficiency`` (cached, bisected once);
  * the two-channel pipeline recurrence (:func:`pipeline_vec`): a scan
    over the uniform step lists — ``group`` iterations of ``(S,)`` array
    ops, replicating the scalar accumulation order *bit for bit* so
    batched totals match ``simulate()`` exactly, ties included.

Quick start (the whole grid in three lines)::

    from repro.core import MI300X, TABLE_I, explore_grid
    ex = explore_grid(TABLE_I, machines=[MI300X])
    print(ex.summary())          # accuracy / speedups over S x M x schedules

Machines are looped (there are a handful), scenarios are vectorized
(there are thousands) — the Python-level work is ``O(M x schedules x
group)`` regardless of S.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import inefficiency as ineff
from repro.core.engine import (  # canonical home: repro.core.engine
    GRID_SCHEDULES,
    SCHEDULE_INDEX,
    GridResult,
)
from repro.core.machine import MachineSpec, Topology
from repro.core.schedule_types import STUDIED, Schedule
from repro.core.workload import (
    GemmShape,
    RaggedScenario,
    Scenario,
    StepProfile,
)

_F = np.float64


# ---------------------------------------------------------------------------
# Scenario batches.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioBatch:
    """Struct-of-arrays view of S global GEMM scenarios."""

    m: np.ndarray  # (S,) int64
    n: np.ndarray  # (S,) int64
    k: np.ndarray  # (S,) int64
    dtype_bytes: np.ndarray  # (S,) int64
    names: tuple[str, ...] = ()

    def __post_init__(self):
        for f in ("m", "n", "k", "dtype_bytes"):
            a = getattr(self, f)
            if a.ndim != 1 or a.shape != self.m.shape:
                raise ValueError(f"{f} must be 1-D and congruent, got {a.shape}")

    def __len__(self) -> int:
        return self.m.shape[0]

    @classmethod
    def from_gemms(cls, gemms, names=()) -> "ScenarioBatch":
        gemms = list(gemms)
        return cls(
            m=np.array([g.m for g in gemms], dtype=np.int64),
            n=np.array([g.n for g in gemms], dtype=np.int64),
            k=np.array([g.k for g in gemms], dtype=np.int64),
            dtype_bytes=np.array(
                [g.dtype_bytes for g in gemms], dtype=np.int64
            ),
            names=tuple(names),
        )

    @classmethod
    def from_scenarios(cls, scenarios) -> "ScenarioBatch":
        scenarios = list(scenarios)
        return cls.from_gemms(
            (s.gemm for s in scenarios), names=tuple(s.name for s in scenarios)
        )

    def gemm(self, i: int) -> GemmShape:
        return GemmShape(
            int(self.m[i]), int(self.n[i]), int(self.k[i]),
            int(self.dtype_bytes[i]),
        )


def _as_batch(scenarios) -> ScenarioBatch:
    if isinstance(scenarios, ScenarioBatch):
        return scenarios
    scenarios = list(scenarios)
    if scenarios and isinstance(scenarios[0], (Scenario, RaggedScenario)):
        return ScenarioBatch.from_scenarios(scenarios)
    return ScenarioBatch.from_gemms(scenarios)


@dataclasses.dataclass(frozen=True)
class RaggedBatch(ScenarioBatch):
    """Struct-of-arrays view of S *ragged* scenarios.

    ``frac`` is the ``(S, P)`` padded per-step fraction matrix (rows sum
    to 1; zero entries are masked tail / empty steps).  Mixed profile
    lengths batch together by zero-padding to the longest profile —
    the masked scan charges padded steps exactly nothing.
    """

    frac: np.ndarray = None  # (S, P) float64

    def __post_init__(self):
        super().__post_init__()
        if self.frac is None:
            raise ValueError("RaggedBatch requires a frac matrix")
        if self.frac.ndim != 2 or self.frac.shape[0] != self.m.shape[0]:
            raise ValueError(
                f"frac must be (S, P) with S={self.m.shape[0]}, "
                f"got {self.frac.shape}"
            )

    @property
    def max_steps(self) -> int:
        return self.frac.shape[1]

    @property
    def imbalance(self) -> np.ndarray:
        """(S,) max/mean active-step share (1.0 == uniform)."""
        active = self.frac > 0.0
        return self.frac.max(axis=1) * active.sum(axis=1)

    @property
    def active_steps(self) -> np.ndarray:
        """(S,) count of non-empty pipeline steps (float64).

        The single source of the "active" convention (strictly positive
        share) — the explorer's skew-aware gate features and
        ``repro.learn.features`` both read this, so the training
        features and the applied features cannot drift apart.
        """
        return (self.frac > 0.0).sum(axis=1).astype(np.float64)

    def profile(self, i: int) -> StepProfile:
        return StepProfile(tuple(float(f) for f in self.frac[i])).trimmed()

    @classmethod
    def from_ragged_scenarios(cls, scenarios) -> "RaggedBatch":
        scenarios = list(scenarios)
        p_max = max(s.profile.steps for s in scenarios)
        frac = np.zeros((len(scenarios), p_max), dtype=_F)
        for i, s in enumerate(scenarios):
            frac[i, : s.profile.steps] = s.profile.fractions
        base = ScenarioBatch.from_scenarios(scenarios)
        return cls(
            m=base.m, n=base.n, k=base.k, dtype_bytes=base.dtype_bytes,
            names=base.names, frac=frac,
        )

    @classmethod
    def from_batch_and_profiles(cls, sb: ScenarioBatch, profiles) -> "RaggedBatch":
        profiles = list(profiles)
        if len(profiles) != len(sb):
            raise ValueError(
                f"{len(profiles)} profiles for {len(sb)} scenarios"
            )
        p_max = max(p.steps for p in profiles)
        frac = np.zeros((len(sb), p_max), dtype=_F)
        for i, p in enumerate(profiles):
            frac[i, : p.steps] = p.fractions
        return cls(
            m=sb.m, n=sb.n, k=sb.k, dtype_bytes=sb.dtype_bytes,
            names=sb.names, frac=frac,
        )


def _as_ragged_batch(scenarios) -> RaggedBatch:
    if isinstance(scenarios, RaggedBatch):
        return scenarios
    scenarios = list(scenarios)
    if not (scenarios and isinstance(scenarios[0], RaggedScenario)):
        raise TypeError(
            "ragged evaluation needs RaggedScenario items or a RaggedBatch"
        )
    return RaggedBatch.from_ragged_scenarios(scenarios)


# ---------------------------------------------------------------------------
# Vectorized roofline GEMM model (mirror of inefficiency.gemm_exec).
# ---------------------------------------------------------------------------


def gemm_exec_vec(
    m: np.ndarray,
    n: np.ndarray,
    k: np.ndarray,
    b: np.ndarray,
    machine: MachineSpec,
    *,
    accumulate: bool = False,
) -> np.ndarray:
    """Elementwise ``inefficiency.gemm_exec(...).time`` over shape arrays.

    Every operation replicates the scalar model's expression order so the
    results agree to the last ulp.  Lanes with ``m == 0`` (degenerate
    decompositions the scalar model would reject) yield NaN.
    """
    t_mn, pu = machine.tile_mn, machine.parallel_units
    # Clamp to >= 1 tile: ragged profiles can produce sub-row fractional
    # chunks whose floor-div would yield 0 tiles (0/0 occupancy).  A
    # no-op for integer m, n >= 1, so the uniform grid is untouched.
    cm = np.maximum((m + t_mn - 1) // t_mn, 1)
    cn = np.maximum((n + t_mn - 1) // t_mn, 1)
    tiles = cm * cn
    split_cap = np.where(m <= t_mn, 2, 8)
    ceil_pu = (pu + tiles - 1) // np.maximum(tiles, 1)
    splits = np.minimum(
        np.minimum(ceil_pu, np.maximum(k // machine.tile_k, 1)), split_cap
    )
    splits = np.where(tiles < pu, splits, 1)
    work = tiles * splits
    padded_flops = 2.0 * (cm * t_mn) * (cn * t_mn) * k
    with np.errstate(divide="ignore", invalid="ignore"):
        occ_quant = work / (-(-work // pu) * pu)
        occ_smooth = np.minimum(1.0, work / pu)
        occupancy = 0.5 * (occ_quant + occ_smooth)
        k_eff = k / (k + machine.tile_k)
        compute = (
            padded_flops
            / machine.peak_flops
            / np.maximum(occupancy * k_eff, 1e-9)
        )
        bytes_hbm = (m * k + k * n + m * n).astype(_F) * b
        if accumulate:
            bytes_hbm = bytes_hbm + (m * n).astype(_F) * b
        bytes_hbm = bytes_hbm + np.where(
            splits > 1, 2.0 * (splits - 1) * (m * n).astype(_F) * 4, 0.0
        )
        memory = bytes_hbm / machine.hbm_bw
        base = np.maximum(compute, memory)
        ramp = machine.kernel_ramp
        t = machine.kernel_latency + base * (1.0 + ramp / (base + ramp))
    return np.where(m > 0, t, np.nan)


# ---------------------------------------------------------------------------
# Vectorized communication model.
# ---------------------------------------------------------------------------


def comm_time_vec(
    nbytes_per_link: np.ndarray,
    machine: MachineSpec,
    *,
    s_half: float,
    n_transfers: int = 1,
) -> np.ndarray:
    per = nbytes_per_link / max(n_transfers, 1)
    t_one = machine.link_latency + (per + s_half) / machine.link_bw
    return n_transfers * t_one


def ag_serial_time_vec(
    mk_bytes: np.ndarray, machine: MachineSpec
) -> np.ndarray:
    g = machine.group
    if machine.topology is Topology.FULL_MESH:
        per_link = mk_bytes / g
    else:
        per_link = mk_bytes * (g - 1) / g / machine.a2a_links
    return comm_time_vec(
        per_link, machine, s_half=ineff.calibrated_s_half(machine)
    )


def p2p_step_time_vec(
    shard_bytes: np.ndarray, machine: MachineSpec
) -> np.ndarray:
    return comm_time_vec(
        shard_bytes / machine.p2p_links,
        machine,
        s_half=ineff.calibrated_s_half(machine),
    )


def a2a_chunk_step_time_vec(
    chunk_bytes: np.ndarray, machine: MachineSpec
) -> np.ndarray:
    g = machine.group
    if machine.topology is Topology.FULL_MESH:
        per_link, n = chunk_bytes, 1
    else:
        per_link = chunk_bytes * (g - 1) / machine.a2a_links
        n = max((g - 1) // machine.a2a_links, 1)
    return comm_time_vec(
        per_link,
        machine,
        s_half=ineff.calibrated_s_half(machine),
        n_transfers=n,
    )


def hbm_move_time_vec(nbytes: np.ndarray, machine: MachineSpec) -> np.ndarray:
    return machine.kernel_latency + 2.0 * nbytes / machine.hbm_bw


# ---------------------------------------------------------------------------
# Vectorized CIL formulas.
# ---------------------------------------------------------------------------


def _mt_norm_vec(m, n, k, b, machine: MachineSpec) -> np.ndarray:
    bytes_mt = (m * k + k * n + m * n).astype(_F) * b
    return bytes_mt / ineff._mt_ref(machine)


def gemm_cil_vec(
    m, n, k, b, machine: MachineSpec, *, degree: int, dma: bool = True
) -> np.ndarray:
    p = 0.5
    c = ineff._cil_coeff(machine, "gemm", degree)
    mt_p = _mt_norm_vec(m, n, k, b, machine) ** p
    cil = 1.0 + c * (min(degree, 3) - 1) * mt_p
    if degree > 3:
        cil = cil * (1.0 + 0.02 * (degree - 3))
    if not dma:
        cil = cil + (ineff.RCCL_EXTRA_GEMM_CIL * mt_p + 0.15)
    return cil


def comm_cil_vec(
    m, n, k, b, machine: MachineSpec, *, degree: int, dma: bool = True
) -> np.ndarray:
    p = 0.5
    c = ineff._cil_coeff(machine, "comm", degree)
    mt_p = _mt_norm_vec(m, n, k, b, machine) ** p
    cil = 1.0 + c * (min(degree, 3) - 1) * mt_p
    if degree > 3:
        cil = cil * (1.0 + 0.02 * (degree - 3))
    if not dma:
        cil = cil + 0.10
    return cil


# ---------------------------------------------------------------------------
# Pipeline recurrence (vectorized scan over uniform step lists).
# ---------------------------------------------------------------------------


def pipeline_vec(comm_steps, compute_steps, deps,
                 comm_active=None, comp_active=None):
    """Vectorized two-channel pipeline over ``(S,)`` step arrays.

    ``comm_steps`` / ``compute_steps`` are short lists (length ~group) of
    per-step time arrays; ``deps[i]`` is the comm step index compute step
    ``i`` waits on (or None).  The scan performs the same additions and
    comparisons, in the same order, as ``simulator._pipeline`` — so
    per-schedule totals agree bit-for-bit with the scalar recurrence
    rather than merely to rounding tolerance.

    ``comm_active`` / ``comp_active`` turn the scan into a **masked
    ragged scan**: matching lists of per-step boolean arrays (or scalars)
    marking real steps.  An inactive step adds exactly 0.0 time and can
    never stall the compute channel, so profiles of different lengths
    batch together zero-padded and reproduce their unpadded recurrences
    bit-for-bit (the same contract as ``jaxgrid.pipeline_jax``).  With
    masks omitted the original uniform code path runs unchanged.

    Returns ``(total, exposed, comm_sum, compute_sum)``.
    """
    finish = []
    t = None
    for s, c in enumerate(comm_steps):
        if comm_active is not None:
            c = np.where(comm_active[s], c, 0.0)
        t = c if t is None else t + c
        finish.append(t)
    zero = np.zeros_like(compute_steps[0])
    t_comp = zero
    exposed = zero
    comp_sum = None
    for i, w in enumerate(compute_steps):
        if comp_active is not None:
            w = np.where(comp_active[i], w, 0.0)
        dep = deps[i]
        if dep is not None:
            ready = finish[dep]
            stalled = ready > t_comp
            if comp_active is not None:
                stalled = stalled & comp_active[i]
            exposed = exposed + np.where(stalled, ready - t_comp, 0.0)
            t_comp = np.where(stalled, ready, t_comp)
        t_comp = t_comp + w
        comp_sum = w if comp_sum is None else comp_sum + w
    comm_sum = finish[-1] if finish else zero
    total = np.maximum(t_comp, comm_sum)
    return total, exposed, comm_sum, comp_sum


# ---------------------------------------------------------------------------
# Grid evaluation.
# ---------------------------------------------------------------------------


def _eval_one_machine(
    sb: ScenarioBatch,
    machine: MachineSpec,
    schedules,
    dma: bool,
    dma_into_place: bool,
):
    """All schedules for one machine; returns dict of (L, S) arrays."""
    g = machine.group
    m, n, k, b = sb.m, sb.n, sb.k, sb.dtype_bytes
    S = len(sb)

    dev_n = np.where(n % g == 0, n // g, n)
    mk_bytes = (m * k).astype(_F) * b
    serial_comm = ag_serial_time_vec(mk_bytes, machine)
    serial_gemm = gemm_exec_vec(m, dev_n, k, b, machine)

    m_div = (m % g == 0) & (m > 0)
    k_div = k % g == 0
    m_s = m // g
    m_sg = m_s // g

    out = {
        name: np.full((len(schedules), S), np.nan)
        for name in ("total", "comm_busy", "compute_busy", "exposed")
    }
    steps = np.zeros(len(schedules), dtype=np.int64)
    valid = np.zeros((len(schedules), S), dtype=bool)

    def put(l, ok, total, comm_busy, compute_busy, exposed, n_steps):
        out["total"][l] = np.where(ok, total, np.nan)
        out["comm_busy"][l] = np.where(ok, comm_busy, np.nan)
        out["compute_busy"][l] = np.where(ok, compute_busy, np.nan)
        out["exposed"][l] = np.where(ok, exposed, np.nan)
        steps[l] = n_steps
        valid[l] = ok

    for l, sched in enumerate(schedules):
        if sched is Schedule.SERIAL:
            total = serial_comm + serial_gemm
            put(
                l, np.ones(S, dtype=bool), total, serial_comm, serial_gemm,
                serial_comm, 1,
            )
            continue

        if sched is Schedule.SHARD_P2P:
            shard_bytes = (m_s * k).astype(_F) * b
            c_cil = comm_cil_vec(m_s, dev_n, k, b, machine, degree=2, dma=dma)
            g_cil = gemm_cil_vec(m_s, dev_n, k, b, machine, degree=2, dma=dma)
            t_p2p = p2p_step_time_vec(shard_bytes, machine) * c_cil
            t_gemm = gemm_exec_vec(m_s, dev_n, k, b, machine) * g_cil
            total, exposed, comm_sum, comp_sum = pipeline_vec(
                [t_p2p] * (g - 1),
                [t_gemm] * g,
                [None] + list(range(g - 1)),
            )
            put(l, m_div, total, comm_sum, comp_sum, exposed, g)
            continue

        # ---- FiCCO schedules -----------------------------------------
        if sched is Schedule.UNIFORM_FUSED_2D:
            k_g = k // g
            chunk_bytes = (m_s * k_g).astype(_F) * b
            step = (m, dev_n, k_g)
            gather_bytes = (m * k_g).astype(_F) * b
            scatter_bytes = None
            degree, accumulate = 4, True
            local = None
            per_step_gemms = 1
            ok = m_div & k_div
        elif sched is Schedule.UNIFORM_FUSED_1D:
            chunk_bytes = (m_sg * k).astype(_F) * b
            step = (m_s, dev_n, k)
            gather_bytes = (m_s * k).astype(_F) * b
            scatter_bytes = (m_s * dev_n).astype(_F) * b
            degree, accumulate = 4, False
            local = None
            per_step_gemms = 1
            ok = m_div
        elif sched is Schedule.HETERO_FUSED_1D:
            chunk_bytes = (m_sg * k).astype(_F) * b
            rows = (g - 1) * m_sg
            step = (rows, dev_n, k)
            gather_bytes = (rows * k).astype(_F) * b
            scatter_bytes = (rows * dev_n).astype(_F) * b
            degree, accumulate = 3, False
            local = (m_s, dev_n, k)
            per_step_gemms = 1
            ok = m_div & (m_sg >= 1)
        elif sched is Schedule.HETERO_UNFUSED_1D:
            chunk_bytes = (m_sg * k).astype(_F) * b
            step = (m_sg, dev_n, k)
            gather_bytes = np.zeros(S)
            scatter_bytes = ((g - 1) * m_sg * dev_n).astype(_F) * b
            degree, accumulate = 2, False
            local = (m_s, dev_n, k)
            per_step_gemms = g - 1
            ok = m_div & (m_sg >= 1)
        else:  # pragma: no cover
            raise ValueError(sched)

        if dma_into_place:
            gather_bytes = np.zeros(S)
            scatter_bytes = None
            degree = 2
        c_cil = comm_cil_vec(
            m_s, dev_n, k, b, machine, degree=degree, dma=dma
        )
        g_cil = gemm_cil_vec(
            step[0], step[1], step[2], b, machine, degree=degree, dma=dma
        )
        t_comm = a2a_chunk_step_time_vec(chunk_bytes, machine) * c_cil
        t_gemm_step = (
            per_step_gemms
            * gemm_exec_vec(
                step[0], step[1], step[2], b, machine, accumulate=accumulate
            )
            * g_cil
        )
        t_gather = np.where(
            gather_bytes > 0, hbm_move_time_vec(gather_bytes, machine), 0.0
        )
        if scatter_bytes is None:
            t_scatter = np.zeros(S)
        else:
            t_scatter = np.where(
                scatter_bytes > 0,
                hbm_move_time_vec(scatter_bytes, machine),
                0.0,
            )
        t_step = np.maximum(t_gemm_step, t_gather + t_scatter)

        if local is not None:
            t_local = gemm_exec_vec(
                local[0], local[1], local[2], b, machine
            ) * gemm_cil_vec(
                local[0], local[1], local[2], b, machine,
                degree=degree, dma=dma,
            )
            compute = [t_local] + [t_step] * g
            deps = [None] + list(range(g))
        else:
            compute = [t_step] * g
            deps = list(range(g))
        total, exposed, comm_sum, comp_sum = pipeline_vec(
            [t_comm] * g, compute, deps
        )
        put(l, ok, total, comm_sum, comp_sum, exposed, g)

    return out, steps, valid, serial_comm, serial_gemm


# ---------------------------------------------------------------------------
# Ragged (non-uniform step) evaluation.
# ---------------------------------------------------------------------------

_FICCO_SCHEDULES = frozenset(STUDIED)


def ragged_step_times(
    m,
    n,
    k,
    b,
    frac,
    machine: MachineSpec,
    sched: Schedule,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
):
    """Per-step stream times of a ragged FiCCO decomposition (one machine).

    ``frac`` is the ``(S, P)`` per-step fraction matrix; step ``s`` of
    scenario ``i`` carries ``frac[i, s]`` of the decomposed dimension
    (capacity rows for the 1D schedules, K columns for 2D), so its comm
    chunk, gathered GEMM rows and gather/scatter traffic all scale with
    it.  The uniform engine is the special case ``frac[i, s] == 1/g``
    with ``P == g``.

    Returns ``(comm_steps, compute_steps, deps, comm_active, comp_active,
    ok)`` — lists over the (local-step +) P pipeline steps of ``(S,)``
    arrays/masks, ready for the masked :func:`pipeline_vec`.  This is the
    single source of truth for per-step times: the NumPy engine consumes
    it batched and the scalar ``simulate(..., profile=...)`` path calls
    it with ``S == 1``, so the two can only disagree in their pipeline
    scans (which the differential tests pin to each other and to the
    independent jax implementation).
    """
    if sched not in _FICCO_SCHEDULES:
        raise ValueError(
            f"ragged profiles apply to the FiCCO schedules, got {sched}"
        )
    g = machine.group
    S = m.shape[0]
    P = frac.shape[1]
    dev_n = np.where(n % g == 0, n // g, n)
    m_div = (m % g == 0) & (m > 0)
    m_s = m // g
    mf = m.astype(_F)
    msf = m_s.astype(_F)
    kf = k.astype(_F)

    if sched is Schedule.UNIFORM_FUSED_2D:
        degree, accumulate = 4, True
        local = None
        per_step_gemms = 1
    elif sched is Schedule.UNIFORM_FUSED_1D:
        degree, accumulate = 4, False
        local = None
        per_step_gemms = 1
    elif sched is Schedule.HETERO_FUSED_1D:
        degree, accumulate = 3, False
        local = (m_s, dev_n, k)
        per_step_gemms = 1
    else:  # HETERO_UNFUSED_1D
        degree, accumulate = 2, False
        local = (m_s, dev_n, k)
        per_step_gemms = g - 1
    if dma_into_place:
        degree = 2
    c_cil = comm_cil_vec(m_s, dev_n, k, b, machine, degree=degree, dma=dma)

    comm_steps, compute_steps = [], []
    comm_active, comp_active = [], []
    for s in range(P):
        f = frac[:, s]
        act = f > 0.0
        if sched is Schedule.UNIFORM_FUSED_2D:
            # The K reduction is cut raggedly; M stays whole per step.
            k_s = f * kf
            chunk_bytes = msf * k_s * b
            rows, cols, inner = mf, dev_n, k_s
            gather_bytes = mf * k_s * b
            scatter_bytes = None
        else:
            chunk_bytes = (f * msf) * kf * b
            cols, inner = dev_n, k
            if sched is Schedule.UNIFORM_FUSED_1D:
                rows = f * mf  # gathered step rows across the whole group
                gather_bytes = rows * kf * b
                scatter_bytes = rows * dev_n * b
            elif sched is Schedule.HETERO_FUSED_1D:
                rows = f * ((g - 1) * msf)  # remote rows only
                gather_bytes = rows * kf * b
                scatter_bytes = rows * dev_n * b
            else:  # HETERO_UNFUSED_1D: g-1 per-peer GEMMs per step
                rows = f * msf
                gather_bytes = None
                scatter_bytes = (g - 1) * rows * dev_n * b
        if dma_into_place:
            gather_bytes = None
            scatter_bytes = None
        t_comm = a2a_chunk_step_time_vec(chunk_bytes, machine) * c_cil
        g_cil = gemm_cil_vec(
            rows, cols, inner, b, machine, degree=degree, dma=dma
        )
        t_gemm = (
            per_step_gemms
            * gemm_exec_vec(
                rows, cols, inner, b, machine, accumulate=accumulate
            )
            * g_cil
        )
        if gather_bytes is None:
            t_gather = np.zeros(S)
        else:
            t_gather = np.where(
                gather_bytes > 0,
                hbm_move_time_vec(gather_bytes, machine),
                0.0,
            )
        if scatter_bytes is None:
            t_scatter = np.zeros(S)
        else:
            t_scatter = np.where(
                scatter_bytes > 0,
                hbm_move_time_vec(scatter_bytes, machine),
                0.0,
            )
        t_step = np.maximum(t_gemm, t_gather + t_scatter)
        comm_steps.append(t_comm)
        comm_active.append(act)
        compute_steps.append(t_step)
        comp_active.append(act)

    if local is not None:
        t_local = gemm_exec_vec(
            local[0], local[1], local[2], b, machine
        ) * gemm_cil_vec(
            local[0], local[1], local[2], b, machine, degree=degree, dma=dma
        )
        compute_steps = [t_local] + compute_steps
        comp_active = [np.ones(S, dtype=bool)] + comp_active
        deps: list[int | None] = [None] + list(range(P))
    else:
        deps = list(range(P))
    return comm_steps, compute_steps, deps, comm_active, comp_active, m_div


def _eval_one_machine_ragged(
    rb: RaggedBatch,
    machine: MachineSpec,
    schedules,
    dma: bool,
    dma_into_place: bool,
):
    """All schedules for one machine over ragged scenarios; (L, S) arrays.

    SERIAL and SHARD_P2P are profile-independent (they move the same
    aggregate bytes whatever the skew) and replicate the uniform engine
    exactly; the FiCCO schedules run the masked ragged scan.
    """
    g = machine.group
    m, n, k, b = rb.m, rb.n, rb.k, rb.dtype_bytes
    S = len(rb)

    dev_n = np.where(n % g == 0, n // g, n)
    mk_bytes = (m * k).astype(_F) * b
    serial_comm = ag_serial_time_vec(mk_bytes, machine)
    serial_gemm = gemm_exec_vec(m, dev_n, k, b, machine)

    m_div = (m % g == 0) & (m > 0)
    m_s = m // g

    out = {
        name: np.full((len(schedules), S), np.nan)
        for name in ("total", "comm_busy", "compute_busy", "exposed")
    }
    steps = np.zeros(len(schedules), dtype=np.int64)
    valid = np.zeros((len(schedules), S), dtype=bool)

    def put(l, ok, total, comm_busy, compute_busy, exposed, n_steps):
        out["total"][l] = np.where(ok, total, np.nan)
        out["comm_busy"][l] = np.where(ok, comm_busy, np.nan)
        out["compute_busy"][l] = np.where(ok, compute_busy, np.nan)
        out["exposed"][l] = np.where(ok, exposed, np.nan)
        steps[l] = n_steps
        valid[l] = ok

    for l, sched in enumerate(schedules):
        if sched is Schedule.SERIAL:
            total = serial_comm + serial_gemm
            put(
                l, np.ones(S, dtype=bool), total, serial_comm, serial_gemm,
                serial_comm, 1,
            )
            continue
        if sched is Schedule.SHARD_P2P:
            shard_bytes = (m_s * k).astype(_F) * b
            c_cil = comm_cil_vec(m_s, dev_n, k, b, machine, degree=2, dma=dma)
            g_cil = gemm_cil_vec(m_s, dev_n, k, b, machine, degree=2, dma=dma)
            t_p2p = p2p_step_time_vec(shard_bytes, machine) * c_cil
            t_gemm = gemm_exec_vec(m_s, dev_n, k, b, machine) * g_cil
            total, exposed, comm_sum, comp_sum = pipeline_vec(
                [t_p2p] * (g - 1),
                [t_gemm] * g,
                [None] + list(range(g - 1)),
            )
            put(l, m_div, total, comm_sum, comp_sum, exposed, g)
            continue
        comm, compute, deps, c_act, w_act, ok = ragged_step_times(
            m, n, k, b, rb.frac, machine, sched,
            dma=dma, dma_into_place=dma_into_place,
        )
        total, exposed, comm_sum, comp_sum = pipeline_vec(
            comm, compute, deps, c_act, w_act
        )
        put(l, ok, total, comm_sum, comp_sum, exposed, rb.max_steps)

    return out, steps, valid, serial_comm, serial_gemm


def _assemble_grid(
    sb: ScenarioBatch,
    machines,
    schedules,
    dma: bool,
    eval_one,
) -> GridResult:
    """Machine-loop assembly shared by the uniform and ragged engines."""
    machines = tuple(machines)
    L, S, M = len(schedules), len(sb), len(machines)
    total = np.empty((L, S, M))
    comm_busy = np.empty((L, S, M))
    compute_busy = np.empty((L, S, M))
    exposed = np.empty((L, S, M))
    steps = np.empty((L, M), dtype=np.int64)
    serial_comm = np.empty((S, M))
    serial_gemm = np.empty((S, M))
    valid = np.empty((L, S, M), dtype=bool)
    for j, machine in enumerate(machines):
        out, st, va, sc, sg = eval_one(machine)
        total[:, :, j] = out["total"]
        comm_busy[:, :, j] = out["comm_busy"]
        compute_busy[:, :, j] = out["compute_busy"]
        exposed[:, :, j] = out["exposed"]
        steps[:, j] = st
        valid[:, :, j] = va
        serial_comm[:, j] = sc
        serial_gemm[:, j] = sg
    return GridResult(
        schedules=tuple(schedules),
        scenarios=sb,
        machines=machines,
        total=total,
        comm_busy=comm_busy,
        compute_busy=compute_busy,
        exposed=exposed,
        steps=steps,
        serial_comm=serial_comm,
        serial_gemm=serial_gemm,
        valid=valid,
        dma=dma,
    )


def evaluate_ragged_grid(
    scenarios,
    machines,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
) -> GridResult:
    """Ragged counterpart of :func:`evaluate_grid`.

    ``scenarios`` is a :class:`RaggedBatch` or a list of
    :class:`~repro.core.workload.RaggedScenario`.  Mixed profile lengths
    batch together (padded + masked).  Returns the same
    :class:`GridResult` shape as the uniform engine, so everything
    downstream (``GridExploration``, benchmarks, tuners) works unchanged.
    """
    rb = _as_ragged_batch(scenarios)
    return _assemble_grid(
        rb, machines, schedules, dma,
        lambda machine: _eval_one_machine_ragged(
            rb, machine, schedules, dma, dma_into_place
        ),
    )


def evaluate_grid(
    scenarios,
    machines,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
) -> GridResult:
    """Evaluate all ``schedules`` for S scenarios x M machines at once.

    ``scenarios`` may be a :class:`ScenarioBatch`, a list of
    :class:`~repro.core.workload.Scenario`, or a list of
    :class:`~repro.core.workload.GemmShape`.
    """
    sb = _as_batch(scenarios)
    return _assemble_grid(
        sb, machines, schedules, dma,
        lambda machine: _eval_one_machine(
            sb, machine, schedules, dma, dma_into_place
        ),
    )


__all__ = [
    "GRID_SCHEDULES",
    "SCHEDULE_INDEX",
    "ScenarioBatch",
    "RaggedBatch",
    "GridResult",
    "evaluate_grid",
    "evaluate_ragged_grid",
    "ragged_step_times",
    "gemm_exec_vec",
    "comm_time_vec",
    "ag_serial_time_vec",
    "p2p_step_time_vec",
    "a2a_chunk_step_time_vec",
    "hbm_move_time_vec",
    "gemm_cil_vec",
    "comm_cil_vec",
    "pipeline_vec",
]
