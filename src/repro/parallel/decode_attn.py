"""Distributed decode attention: shard_map flash-decode over the cache.

§Perf pair-2 finding: with the KV cache time-sharded over the ``model``
axis, GSPMD materializes gathered K/V slices for every decode step
(~4.6 GB/step for yi-9b x decode_32k) because it partitions the
scores -> softmax -> AV chain op-by-op.  The fix is the same move FiCCO
makes for GEMMs: take the data-dependent pattern out of the implicit
partitioner and express it explicitly.

Each device holds a contiguous time-slice of the cache, performs the
in-place cache update if ``pos`` lands in its slice (masked write — shape
static), computes *partial* attention with local max/denominator, and the
group combines with one tiny pmax + two psums of (B, H)-sized statistics:

    m   = pmax_g(m_loc)
    l   = psum_g(l_loc * exp(m_loc - m))
    out = psum_g(o_loc * exp(m_loc - m)) / l

Collectives per layer drop from O(B * S * KV * hd) gathered bytes to
O(B * H * hd) — measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, _active_mesh

_NEG_INF = -1e30


def applicable(k_cache: jax.Array, window) -> bool:
    mesh = _active_mesh()
    if mesh is None or MODEL_AXIS not in mesh.shape:
        return False
    g = mesh.shape[MODEL_AXIS]
    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
    dp = math.prod(mesh.shape[a] for a in batch_axes) if batch_axes else 1
    return (
        g > 1
        and window is None
        and k_cache.shape[1] % g == 0
        and k_cache.shape[1] >= 1024
        and k_cache.shape[0] % dp == 0
    )


def shard_map_attn_decode(
    q: jax.Array,  # (B, 1, H, D) — post-RoPE
    k_new: jax.Array,  # (B, 1, KV, D) — post-RoPE
    v_new: jax.Array,  # (B, 1, KV, D)
    k_cache: jax.Array,  # (B, S, KV, D), time-sharded over `model`
    v_cache: jax.Array,
    pos,  # scalar int32
):
    """Returns (out (B, 1, H, D), new_k_cache, new_v_cache)."""
    mesh = _active_mesh()
    g = mesh.shape[MODEL_AXIS]
    b, s, kv, d = k_cache.shape
    h = q.shape[2]
    s_loc = s // g
    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
    bspec = batch_axes if batch_axes else None

    def body(q, k_new, v_new, k_c, v_c, pos):
        me = lax.axis_index(MODEL_AXIS)
        offset = me * s_loc
        local_idx = jnp.arange(s_loc)
        # masked in-place write (shard-local; no cross-device traffic)
        write = (local_idx + offset == pos)[None, :, None, None]
        k_c = jnp.where(write, k_new.astype(k_c.dtype), k_c)
        v_c = jnp.where(write, v_new.astype(v_c.dtype), v_c)

        rep = h // kv
        kr = jnp.repeat(k_c, rep, axis=2)  # (B, s_loc, H, D)
        vr = jnp.repeat(v_c, rep, axis=2)
        scores = jnp.einsum(
            "bqhd,bkhd->bhqk",
            q.astype(jnp.float32),
            kr.astype(jnp.float32),
        ) / math.sqrt(d)
        valid = (local_idx + offset <= pos)[None, None, None, :]
        scores = jnp.where(valid, scores, _NEG_INF)
        m_loc = jnp.max(scores, -1)  # (B, H, 1)
        p = jnp.exp(scores - m_loc[..., None])
        p = jnp.where(valid, p, 0.0)
        l_loc = jnp.sum(p, -1)  # (B, H, 1)
        o_loc = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))

        m_g = lax.pmax(m_loc, MODEL_AXIS)
        corr = jnp.exp(m_loc - m_g)
        l_g = lax.psum(l_loc * corr, MODEL_AXIS)
        o_g = lax.psum(
            o_loc * corr.transpose(0, 2, 1)[..., None], MODEL_AXIS
        )
        out = (o_g / jnp.maximum(l_g, 1e-30).transpose(0, 2, 1)[..., None])
        return out.astype(q.dtype), k_c, v_c

    rep_spec = P(bspec, None, None, None)
    cache_spec = P(bspec, MODEL_AXIS, None, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec,
                  P()),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_vma=False,
    )(q, k_new, v_new, k_cache, v_cache, pos)
