"""Runtime overlap context: which FiCCO mode the current jit trace uses.

Set by the launcher/train driver around tracing; read by the TP layers so
the same model code runs GSPMD-serial (baseline) or FiCCO-overlapped
without plumbing a flag through every layer signature.
"""

from __future__ import annotations

import contextlib
import threading

from repro.configs.base import OverlapConfig

_STATE = threading.local()


def get_overlap() -> OverlapConfig | None:
    return getattr(_STATE, "overlap", None)


@contextlib.contextmanager
def overlap_context(cfg: OverlapConfig | None):
    prev = get_overlap()
    _STATE.overlap = cfg
    try:
        yield
    finally:
        _STATE.overlap = prev
