"""Tensor-sequence-parallel linears with FiCCO overlap (paper Fig. 3).

``tp_ficco_linear`` is the production integration point: activations enter
sequence-sharded over the ``model`` axis (Megatron sequence parallelism),
the weight is column-sharded, and the data-dependent AG->GEMM is executed
by a bespoke FiCCO schedule chosen from the static GEMM dims (Fig. 12a) —
exactly the paper's drop-in replacement for serial collective+GEMM.

Modes (config.overlap.mode):
  * "gspmd_serial" — not handled here; plain constraints, XLA collectives.
  * "serial" / "shard_p2p" / "ficco_auto" / "ficco_autotune" / explicit
    schedule value — shard_map with the corresponding schedule from
    repro.overlap ("ficco_autotune" consults the persistent runtime
    tuner in repro.autotune, falling back to the static heuristic).
Backend "pallas_dma" swaps the chunk exchange for the Pallas ICI-DMA
kernel (repro.kernels) — the paper's DMA offload made explicit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import OverlapConfig
from repro.core.machine import TPU_V5E
from repro.core.schedule_types import Schedule
from repro.overlap.api import ficco_linear
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, _active_mesh


def _mode_to_schedule(mode: str):
    if mode == "ficco_auto":
        return "auto"
    if mode == "ficco_autotune":
        return "autotune"
    return mode  # Schedule enum value string or "serial"/"shard_p2p"


def overlap_applicable(x: jax.Array, w: jax.Array) -> bool:
    mesh = _active_mesh()
    if mesh is None or MODEL_AXIS not in mesh.shape:
        return False
    g = mesh.shape[MODEL_AXIS]
    if g <= 1:
        return False
    b, s, d = x.shape
    return s % g == 0 and w.shape[1] % g == 0


def tp_ficco_linear(
    x: jax.Array,
    w: jax.Array,
    overlap: OverlapConfig,
) -> jax.Array:
    """x: (B, S, D) -> (B, S, F) with FiCCO-overlapped AG->GEMM.

    The activation is constrained sequence-sharded over ``model`` (the
    tensor-sequence-parallel start state of paper Fig. 3a); inside the
    shard_map each device holds (B_local, S/g, D) and computes the full-S
    x (F/g) output block via the selected schedule.
    """
    mesh = _active_mesh()
    g = mesh.shape[MODEL_AXIS]
    b, s, d = x.shape
    f = w.shape[1]
    schedule = _mode_to_schedule(overlap.mode)

    def body(x_shard, w_shard):
        # (B_local, S/g, D) -> rows ordered seq-major so the all-gather's
        # device-major concatenation reconstructs the global seq order.
        b_local = x_shard.shape[0]
        rows = x_shard.transpose(1, 0, 2).reshape(-1, d)  # (S/g*B, D)
        if overlap.backend == "pallas_dma" and schedule in (
            "auto", Schedule.UNIFORM_FUSED_1D.value
        ) and rows.shape[0] % g == 0:
            from repro.kernels.ops import ag_matmul_dma

            out = ag_matmul_dma(rows, w_shard, axis_name=MODEL_AXIS)
        else:
            out = ficco_linear(
                rows,
                w_shard,
                axis_name=MODEL_AXIS,
                schedule=schedule,
                machine=TPU_V5E,
            )
        # out: (S * B_local, F/g) -> (B_local, S, F/g)
        return out.reshape(s, b_local, f // g).transpose(1, 0, 2)

    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
    bspec = batch_axes if batch_axes else None
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bspec, MODEL_AXIS, None), P(None, MODEL_AXIS)),
        out_specs=P(bspec, None, MODEL_AXIS),
        check_vma=False,
    )(x, w)
