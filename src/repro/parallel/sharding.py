"""Mesh-aware sharding helpers.

Logical axes used throughout the model code:
  * batch dims  -> ("pod", "data")   (pure data parallel across pods)
  * model dims  -> "model"           (TP / EP / head / expert sharding)
  * sequence    -> "data" for the context-parallel long-decode cache

``constrain`` degrades to a no-op when no mesh is active (single-device
smoke tests) and silently drops axis names the active mesh does not have
(so the same model code runs on (data, model), (pod, data, model) and
single-device meshes).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def _active_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = None  # old JAX: no abstract-mesh API; try the physical mesh
    if mesh is None or not mesh.shape:
        # fall back to the concrete mesh context if one is entered
        try:
            from jax.interpreters import pxla

            mesh = pxla.thread_resources.env.physical_mesh
            if mesh.empty:
                return None
        except Exception:
            return None
    return mesh


def _filter_spec(spec: P, axis_names) -> P:
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in axis_names else None
        sub = tuple(a for a in entry if a in axis_names)
        return sub if sub else None

    return P(*(keep(e) for e in spec))


def constrain(x: jax.Array, *spec_entries) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (or no-op).

    Entries are dropped when the mesh lacks the axis OR the dimension is
    not divisible by the axis size (e.g. kv=4 heads on a 16-way model
    axis) — uneven shardings trigger involuntary full rematerialization
    in the SPMD partitioner.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = set()
    for n in mesh.shape:
        names.add(n)
    spec = _filter_spec(P(*spec_entries), names)
    entries = list(spec) + [None] * (x.ndim - len(spec))
    for i, e in enumerate(entries):
        if e is None:
            continue
        size = _axis_size(mesh, e)
        if size <= 1 or x.shape[i] % size:
            entries[i] = None
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        return x


def batch_spec(*rest) -> tuple:
    """Spec entries for a (batch, ...) activation."""
    return (BATCH_AXES, *rest)


def filter_pspec(spec: P, mesh) -> P:
    """Public helper: drop axes absent from ``mesh`` from a PartitionSpec."""
    return _filter_spec(spec, set(mesh.shape))


# ---------------------------------------------------------------------------
# Launch-time spec fix-up: divisibility + FSDP augmentation
# ---------------------------------------------------------------------------

def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape.get(entry, 1)
    n = 1
    for a in entry:
        n *= mesh.shape.get(a, 1)
    return n


def fix_param_spec(spec: P, shape, mesh, *, fsdp_axis: str = "data") -> P:
    """Make a parameter spec legal + memory-efficient on ``mesh``:

      1. drop axes the mesh doesn't have,
      2. drop entries whose dimension is not divisible by the axis size
         (e.g. seamless's 256206 vocab over a 16-way axis),
      3. FSDP: if the ``data`` axis is unused and the leaf is a real weight
         (>= 2 dims, >= 2^16 elements), shard its largest divisible,
         not-yet-sharded dimension over ``data`` — this is what keeps
         400B-class models' parameters + Adam moments within HBM at 256
         chips (ZeRO-3-style 2D weight sharding).
    """
    import math

    names = set(mesh.shape)
    spec = _filter_spec(spec, names)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used: set[str] = set()
    for i, e in enumerate(entries):
        if e is None:
            continue
        size = _axis_size(mesh, e)
        if shape[i] % size:
            entries[i] = None
            continue
        used.update([e] if isinstance(e, str) else list(e))
    n_elems = math.prod(shape) if shape else 1
    if (
        fsdp_axis in names
        and fsdp_axis not in used
        and len(shape) >= 2
        and n_elems >= 1 << 16
    ):
        ax = mesh.shape[fsdp_axis]
        candidates = [
            i
            for i in range(len(shape))
            if entries[i] is None and shape[i] % ax == 0 and shape[i] >= ax
        ]
        if candidates:
            best = max(candidates, key=lambda i: shape[i])
            entries[best] = fsdp_axis
    return P(*entries)


def fix_param_specs(specs, shapes, mesh) -> "object":
    """Tree version of fix_param_spec (specs/shapes share structure)."""
    return jax.tree.map(
        lambda sp, sh: fix_param_spec(sp, sh.shape, mesh),
        specs,
        shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def cache_leaf_spec(shape, mesh) -> P:
    """Decode-cache sharding rule.

    Layout (periods, B, ...): batch over (pod, data) when divisible; the
    largest remaining dimension >= 1024 divisible by the model axis is
    sharded over 'model' (the 32k KV time axis, or Mamba's d_inner);
    when batch is unsharded (long_500k B=1) the 'data' axis joins the
    sequence dimension — context-parallel cache reads.
    """
    names = set(mesh.shape)
    rank = len(shape)
    entries: list = [None] * rank
    dp = 1
    batch_axes = tuple(a for a in BATCH_AXES if a in names)
    for a in batch_axes:
        dp *= mesh.shape[a]
    batch_sharded = False
    if rank >= 2 and dp > 1 and shape[1] % dp == 0 and shape[1] >= dp:
        entries[1] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        batch_sharded = True
    model = mesh.shape.get(MODEL_AXIS, 1)
    rest = sorted(
        range(2, rank), key=lambda i: shape[i], reverse=True
    )
    model_used = False
    for i in rest:
        if (
            not model_used
            and model > 1
            and shape[i] >= 1024
            and shape[i] % model == 0
        ):
            if not batch_sharded and dp > 1 and shape[i] % (model * dp) == 0:
                entries[i] = (*batch_axes, MODEL_AXIS)
            else:
                entries[i] = MODEL_AXIS
            model_used = True
            break
    return P(*entries)


def cache_specs(cache_shapes, mesh):
    return jax.tree.map(
        lambda l: cache_leaf_spec(l.shape, mesh), cache_shapes
    )
