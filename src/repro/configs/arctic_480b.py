"""Config for --arch arctic-480b (see registry for the citation)."""

from repro.configs.registry import arctic_480b as _make


def make_config():
    return _make()
