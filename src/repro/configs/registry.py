"""Architecture registry: the 10 assigned configs (+ aliases).

Every entry cites its source; exact hyperparameters from the assignment.
"""

from __future__ import annotations

from repro.configs.base import (
    EncDecConfig,
    Family,
    FrontendConfig,
    HybridConfig,
    MambaConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    XLSTMConfig,
)


def seamless_m4t_large_v2() -> ModelConfig:
    # [arXiv:2308.11596] SeamlessM4T v2-large: 24L speech encoder (stubbed
    # conformer frontend -> frame embeddings) + 24L text decoder.
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family=Family.AUDIO,
        num_layers=24,  # decoder; encoder layers below
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        # true vocab 256206, padded to a multiple of 256 (Megatron-style)
        # so the unembed/CE shard evenly over the 16-way model axis —
        # unpadded it forces replicated fp32 logits (~67 GB/device).
        vocab_size=256256,
        norm="layernorm",
        encdec=EncDecConfig(encoder_layers=24, encoder_len_ratio=1.0),
        frontend=FrontendConfig(prefix_tokens=0, embed_dim=0),
        citation="arXiv:2308.11596",
    )


def olmo_1b() -> ModelConfig:
    # [arXiv:2402.00838] OLMo-1B: non-parametric LayerNorm, tied embeddings.
    return ModelConfig(
        name="olmo-1b",
        family=Family.DENSE,
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        norm="nonparametric_ln",
        tie_embeddings=True,
        citation="arXiv:2402.00838",
    )


def deepseek_v2_lite_16b() -> ModelConfig:
    # [arXiv:2405.04434] DeepSeek-V2-Lite: MLA (kv_lora 512, rope head 64),
    # 64 routed experts top-6 + 2 shared, expert FFN 1408.
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family=Family.MOE,
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        mla=MLAConfig(
            kv_lora_rank=512, rope_head_dim=64,
            nope_head_dim=128, v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64, top_k=6, num_shared_experts=2,
            d_ff_expert=1408,
        ),
        citation="arXiv:2405.04434",
    )


def arctic_480b() -> ModelConfig:
    # [hf:Snowflake/snowflake-arctic-base] 128 experts top-2 in parallel
    # with a dense residual FFN (dense-MoE hybrid).
    return ModelConfig(
        name="arctic-480b",
        family=Family.MOE,
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        moe=MoEConfig(
            num_experts=128, top_k=2, d_ff_expert=4864,
            dense_residual_ff=4864,
        ),
        citation="hf:Snowflake/snowflake-arctic-base",
    )


def jamba_1_5_large_398b() -> ModelConfig:
    # [arXiv:2403.19887] Jamba: Mamba+attention 1:7, MoE (16e top-2) on
    # every other layer.
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family=Family.HYBRID,
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        hybrid=HybridConfig(
            attn_every=8, attn_offset=4,
            mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        ),
        moe=MoEConfig(
            num_experts=16, top_k=2, d_ff_expert=24576, every_k_layers=2
        ),
        citation="arXiv:2403.19887",
    )


def tinyllama_1_1b() -> ModelConfig:
    # [arXiv:2401.02385] TinyLlama: llama-2 architecture, GQA kv=4.
    return ModelConfig(
        name="tinyllama-1.1b",
        family=Family.DENSE,
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        citation="arXiv:2401.02385",
    )


def smollm_360m() -> ModelConfig:
    # [hf:HuggingFaceTB/SmolLM-360M] llama-arch small; 15 heads, GQA kv=5.
    return ModelConfig(
        name="smollm-360m",
        family=Family.DENSE,
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        citation="hf:HuggingFaceTB/SmolLM-135M",
    )


def yi_9b() -> ModelConfig:
    # [arXiv:2403.04652] Yi-9B: llama arch with GQA kv=4.
    return ModelConfig(
        name="yi-9b",
        family=Family.DENSE,
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        citation="arXiv:2403.04652",
    )


def internvl2_76b() -> ModelConfig:
    # [arXiv:2404.16821] InternVL2-Llama3-76B backbone (the LM that consumes
    # InternViT patch embeddings; ViT stubbed per the carve-out, projector
    # from ViT width 3200 is real).
    return ModelConfig(
        name="internvl2-76b",
        family=Family.VLM,
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        frontend=FrontendConfig(prefix_tokens=256, embed_dim=3200),
        citation="arXiv:2404.16821",
    )


def xlstm_1_3b() -> ModelConfig:
    # [arXiv:2405.04517] xLSTM-1.3B: sLSTM + mLSTM blocks (7:1), no FFN.
    return ModelConfig(
        name="xlstm-1.3b",
        family=Family.SSM,
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm=XLSTMConfig(slstm_every=8, slstm_offset=7, proj_factor=2.0),
        citation="arXiv:2405.04517",
    )


ARCHS = {
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "olmo-1b": olmo_1b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "arctic-480b": arctic_480b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "smollm-360m": smollm_360m,
    "yi-9b": yi_9b,
    "internvl2-76b": internvl2_76b,
    "xlstm-1.3b": xlstm_1_3b,
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]()
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
