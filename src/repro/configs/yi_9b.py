"""Config for --arch yi-9b (see registry for the citation)."""

from repro.configs.registry import yi_9b as _make


def make_config():
    return _make()
