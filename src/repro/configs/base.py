"""Config system: model / parallelism / overlap / run configuration.

Every assigned architecture gets a ``configs/<id>.py`` exposing
``make_config()`` with the exact public-literature hyperparameters; reduced
smoke variants come from :func:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    HYBRID = "hybrid"  # Mamba + attention interleave (Jamba)
    SSM = "ssm"  # xLSTM
    VLM = "vlm"  # vision frontend stub + LM backbone
    AUDIO = "audio"  # enc-dec with audio frontend stub


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0  # per-expert FFN width
    dense_residual_ff: int = 0  # Arctic: dense FFN in parallel with MoE
    every_k_layers: int = 1  # MoE replaces FFN every k-th layer (Jamba: 2)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = full-rank Q projection (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba: attention every k-th layer, Mamba otherwise."""

    attn_every: int = 8  # 1:7 attention:mamba
    attn_offset: int = 4
    mamba: MambaConfig = dataclasses.field(default_factory=MambaConfig)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8  # 7:1 mLSTM:sLSTM
    slstm_offset: int = 7
    proj_factor: float = 2.0
    chunk_size: int = 256  # mLSTM chunkwise-parallel scan chunk


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 24
    # encoder frame count fed by the (stubbed) audio frontend per shape.
    encoder_len_ratio: float = 1.0  # enc frames = ratio * seq_len


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend: input_specs() provides pre-computed
    frame/patch embeddings of this many prefix positions (the one allowed
    carve-out: we implement the LM that consumes them, not the ViT/codec).
    """

    prefix_tokens: int = 256  # VLM: image patches per sample
    embed_dim: int = 0  # 0 -> d_model (projector output dimension)


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """How the paper's technique is applied inside the model."""

    # gspmd_serial: plain sharding constraints, XLA chooses collectives.
    # serial / shard_p2p / ficco_auto / explicit schedule name: shard_map
    # overlap schedules from repro.overlap in the TP linears.
    mode: str = "gspmd_serial"
    backend: str = "xla"  # xla | pallas_dma (DMA kernels from repro.kernels)
    moe_chunks: int = 0  # 0 -> group size (FiCCO EP dispatch chunking)
    # decode attention over a model-axis time-sharded cache:
    # "gspmd" (implicit partitioning) or "shard_map" (explicit flash-decode
    # with partial-softmax psum combine — see parallel/decode_attn.py).
    decode_attn: str = "gspmd"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # long-context behaviour: None = full causal attention;
    # "sliding_window:<W>" enables banded attention with window W (used by
    # full-attention archs to run the long_500k decode shape).
    sliding_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendConfig] = None
    overlap: OverlapConfig = dataclasses.field(default_factory=OverlapConfig)
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # "nothing" = nothing_saveable (min memory, recomputes fwd incl. its
    # collectives); "dots" = dots_saveable (saves GEMM outputs: no GEMM/
    # AG recompute in backward at higher activation memory).
    remat_policy: str = "nothing"
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes: dict = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=d_model // heads,
            dtype="float32",
            remat=False,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 128, 128),
                dense_residual_ff=(
                    128 if self.moe.dense_residual_ff else 0
                ),
            )
        if self.mla:
            changes["mla"] = dataclasses.replace(
                self.mla,
                kv_lora_rank=64,
                rope_head_dim=32,
                nope_head_dim=d_model // heads,
                v_head_dim=d_model // heads,
            )
            changes["head_dim"] = 0
        if self.hybrid:
            changes["hybrid"] = dataclasses.replace(
                self.hybrid, attn_every=2, attn_offset=1
            )
        if self.xlstm:
            changes["xlstm"] = dataclasses.replace(
                self.xlstm, slstm_every=2, slstm_offset=1, chunk_size=16
            )
        if self.encdec:
            changes["encdec"] = dataclasses.replace(
                self.encdec, encoder_layers=2
            )
        if self.frontend:
            changes["frontend"] = dataclasses.replace(
                self.frontend, prefix_tokens=8
            )
        if self.sliding_window:
            changes["sliding_window"] = 32
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
