"""Config for --arch olmo-1b (see registry for the citation)."""

from repro.configs.registry import olmo_1b as _make


def make_config():
    return _make()
