"""Config for --arch deepseek-v2-lite-16b (see registry for the citation)."""

from repro.configs.registry import deepseek_v2_lite_16b as _make


def make_config():
    return _make()
