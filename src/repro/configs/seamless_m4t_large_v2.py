"""Config for --arch seamless-m4t-large-v2 (see registry for the citation)."""

from repro.configs.registry import seamless_m4t_large_v2 as _make


def make_config():
    return _make()
