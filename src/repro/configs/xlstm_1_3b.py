"""Config for --arch xlstm-1.3b (see registry for the citation)."""

from repro.configs.registry import xlstm_1_3b as _make


def make_config():
    return _make()
