"""Config for --arch tinyllama-1.1b (see registry for the citation)."""

from repro.configs.registry import tinyllama_1_1b as _make


def make_config():
    return _make()
