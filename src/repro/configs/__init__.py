from repro.configs.base import (
    SHAPES,
    EncDecConfig,
    Family,
    FrontendConfig,
    HybridConfig,
    MambaConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OverlapConfig,
    ShapeConfig,
    XLSTMConfig,
)
from repro.configs.registry import ARCHS, get_config

__all__ = [
    "SHAPES", "ARCHS", "get_config",
    "EncDecConfig", "Family", "FrontendConfig", "HybridConfig",
    "MambaConfig", "MLAConfig", "ModelConfig", "MoEConfig",
    "OverlapConfig", "ShapeConfig", "XLSTMConfig",
]
