"""Config for --arch smollm-360m (see registry for the citation)."""

from repro.configs.registry import smollm_360m as _make


def make_config():
    return _make()
