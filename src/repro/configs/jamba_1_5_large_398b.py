"""Config for --arch jamba-1.5-large-398b (see registry for the citation)."""

from repro.configs.registry import jamba_1_5_large_398b as _make


def make_config():
    return _make()
