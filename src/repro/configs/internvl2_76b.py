"""Config for --arch internvl2-76b (see registry for the citation)."""

from repro.configs.registry import internvl2_76b as _make


def make_config():
    return _make()
